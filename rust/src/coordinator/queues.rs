//! Per-worker input/output task queues (paper §III "Queues").
//!
//! Worker n maintains an input queue I_n (tasks it will process) and an
//! output queue O_n (tasks staged for offloading). Queue *lengths* drive
//! every decision in Algs 1–4 — the *order* tasks are served in is a
//! policy, owned by the [`crate::sched`] subsystem: [`WorkerQueues`] holds
//! one boxed [`QueueDiscipline`] per queue, built from the run's
//! [`SchedConfig`]. [`TaskQueue`] is the plain FIFO backing store the
//! `sched::Fifo` discipline wraps (and the seed's original structure).

use std::collections::VecDeque;

use super::task::Task;
use crate::sched::{QueueDiscipline, SchedConfig};

/// FIFO task queue with occupancy accounting.
#[derive(Debug, Default)]
pub struct TaskQueue {
    q: VecDeque<Task>,
    peak: usize,
    total_enqueued: u64,
}

impl TaskQueue {
    pub fn new() -> TaskQueue {
        TaskQueue::default()
    }

    pub fn push(&mut self, t: Task) {
        self.q.push_back(t);
        self.peak = self.peak.max(self.q.len());
        self.total_enqueued += 1;
    }

    /// Head-of-line task (both Alg. 1 and Alg. 2 operate on the HoL task).
    pub fn pop(&mut self) -> Option<Task> {
        self.q.pop_front()
    }

    pub fn peek(&self) -> Option<&Task> {
        self.q.front()
    }

    /// Front-to-back iteration (cold-path diagnostics like per-class
    /// occupancy — the hot path never walks the queue).
    pub fn iter(&self) -> impl Iterator<Item = &Task> {
        self.q.iter()
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn peak(&self) -> usize {
        self.peak
    }

    pub fn total_enqueued(&self) -> u64 {
        self.total_enqueued
    }

    /// Drain everything (worker leaving the network hands tasks back).
    /// Yields tasks in arrival (push) order and leaves the `peak` /
    /// `total_enqueued` accounting untouched: the drain is churn
    /// bookkeeping, not service, so a worker that later re-joins keeps a
    /// consistent history.
    pub fn drain_all(&mut self) -> Vec<Task> {
        self.q.drain(..).collect()
    }
}

/// The I_n / O_n pair, each behind the run's configured queue discipline.
#[derive(Debug)]
pub struct WorkerQueues {
    pub input: Box<dyn QueueDiscipline>,
    pub output: Box<dyn QueueDiscipline>,
}

impl WorkerQueues {
    /// `measure_from` is the warmup boundary for drop accounting.
    pub fn new(sched: &SchedConfig, measure_from: f64) -> WorkerQueues {
        WorkerQueues {
            input: sched.build_queue(measure_from),
            output: sched.build_queue(measure_from),
        }
    }

    /// I_n + O_n — the occupancy signal Algs 3 and 4 consume.
    pub fn total_len(&self) -> usize {
        self.input.len() + self.output.len()
    }

    /// Drain both queues in *admission* order (churn re-homing). Each
    /// discipline drains in its own arrival order; interleaving by
    /// admission time (ties by task id) restores the order the source
    /// admitted the data in, so re-homed work replays deterministically.
    pub fn drain_all_ordered(&mut self) -> Vec<Task> {
        let mut tasks = self.input.drain_all();
        tasks.extend(self.output.drain_all());
        tasks.sort_by(Task::admission_cmp);
        tasks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(id: u64) -> Task {
        Task::initial(id, id as usize, None, 0.0)
    }

    #[test]
    fn fifo_order() {
        let mut q = TaskQueue::new();
        q.push(task(1));
        q.push(task(2));
        q.push(task(3));
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.peek().unwrap().id, 2);
        assert_eq!(q.pop().unwrap().id, 2);
        assert_eq!(q.pop().unwrap().id, 3);
        assert!(q.pop().is_none());
    }

    #[test]
    fn occupancy_accounting() {
        let mut q = TaskQueue::new();
        for i in 0..5 {
            q.push(task(i));
        }
        q.pop();
        q.pop();
        q.push(task(9));
        assert_eq!(q.len(), 4);
        assert_eq!(q.peak(), 5);
        assert_eq!(q.total_enqueued(), 6);
    }

    #[test]
    fn drain_preserves_order_and_accounting() {
        let mut q = TaskQueue::new();
        for i in 0..4 {
            q.push(task(i));
        }
        q.pop();
        let (peak, total) = (q.peak(), q.total_enqueued());
        let ids: Vec<u64> = q.drain_all().iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![1, 2, 3], "arrival order");
        assert_eq!(q.len(), 0);
        assert_eq!(q.peak(), peak, "drain must not reset peak");
        assert_eq!(q.total_enqueued(), total, "drain must not reset total_enqueued");
        // post-churn pushes keep accumulating on the same history
        q.push(task(9));
        assert_eq!(q.total_enqueued(), total + 1);
        assert_eq!(q.peak(), peak);
    }

    #[test]
    fn totals_and_drain() {
        let mut w = WorkerQueues::new(&SchedConfig::default(), 0.0);
        w.input.push(task(1));
        w.output.push(task(2));
        w.output.push(task(3));
        assert_eq!(w.total_len(), 3);
        let drained = w.output.drain_all();
        assert_eq!(drained.len(), 2);
        assert_eq!(w.total_len(), 1);
        assert!(w.output.is_empty());
    }

    #[test]
    fn ordered_drain_interleaves_by_admission_time() {
        let at = |id: u64, t: f64| Task::initial(id, 0, None, t);
        let mut w = WorkerQueues::new(&SchedConfig::default(), 0.0);
        // Output holds *older* work (already computed once); input holds
        // newer arrivals — a naive input-then-output drain would invert
        // admission order.
        w.output.push(at(10, 0.1));
        w.output.push(at(11, 0.3));
        w.input.push(at(12, 0.2));
        w.input.push(at(13, 0.4));
        let ids: Vec<u64> = w.drain_all_ordered().iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![10, 12, 11, 13], "admission order across both queues");
        assert_eq!(w.total_len(), 0);
    }
}
