//! Per-worker input/output task queues (paper §III "Queues").
//!
//! Worker n maintains an input queue I_n (tasks it will process) and an
//! output queue O_n (tasks staged for offloading). Queue *lengths* drive
//! every decision in Algs 1–4, so the structure tracks peak occupancy for
//! the reports too.

use std::collections::VecDeque;

use super::task::Task;

/// FIFO task queue with occupancy accounting.
#[derive(Debug, Default)]
pub struct TaskQueue {
    q: VecDeque<Task>,
    peak: usize,
    total_enqueued: u64,
}

impl TaskQueue {
    pub fn new() -> TaskQueue {
        TaskQueue::default()
    }

    pub fn push(&mut self, t: Task) {
        self.q.push_back(t);
        self.peak = self.peak.max(self.q.len());
        self.total_enqueued += 1;
    }

    /// Head-of-line task (both Alg. 1 and Alg. 2 operate on the HoL task).
    pub fn pop(&mut self) -> Option<Task> {
        self.q.pop_front()
    }

    pub fn peek(&self) -> Option<&Task> {
        self.q.front()
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn peak(&self) -> usize {
        self.peak
    }

    pub fn total_enqueued(&self) -> u64 {
        self.total_enqueued
    }

    /// Drain everything (worker leaving the network hands tasks back).
    pub fn drain_all(&mut self) -> Vec<Task> {
        self.q.drain(..).collect()
    }
}

/// The I_n / O_n pair.
#[derive(Debug, Default)]
pub struct WorkerQueues {
    pub input: TaskQueue,
    pub output: TaskQueue,
}

impl WorkerQueues {
    pub fn new() -> WorkerQueues {
        WorkerQueues::default()
    }

    /// I_n + O_n — the occupancy signal Algs 3 and 4 consume.
    pub fn total_len(&self) -> usize {
        self.input.len() + self.output.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(id: u64) -> Task {
        Task::initial(id, id as usize, None, 0.0)
    }

    #[test]
    fn fifo_order() {
        let mut q = TaskQueue::new();
        q.push(task(1));
        q.push(task(2));
        q.push(task(3));
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.peek().unwrap().id, 2);
        assert_eq!(q.pop().unwrap().id, 2);
        assert_eq!(q.pop().unwrap().id, 3);
        assert!(q.pop().is_none());
    }

    #[test]
    fn occupancy_accounting() {
        let mut q = TaskQueue::new();
        for i in 0..5 {
            q.push(task(i));
        }
        q.pop();
        q.pop();
        q.push(task(9));
        assert_eq!(q.len(), 4);
        assert_eq!(q.peak(), 5);
        assert_eq!(q.total_enqueued(), 6);
    }

    #[test]
    fn totals_and_drain() {
        let mut w = WorkerQueues::new();
        w.input.push(task(1));
        w.output.push(task(2));
        w.output.push(task(3));
        assert_eq!(w.total_len(), 3);
        let drained = w.output.drain_all();
        assert_eq!(drained.len(), 2);
        assert_eq!(w.total_len(), 1);
        assert!(w.output.is_empty());
    }
}
