//! The clock abstraction: the only place the coordinator is allowed to
//! touch wallclock time.
//!
//! [`WorkerCore`](super::worker::WorkerCore) never reads time — drivers
//! sample their [`Clock`] and pass `now` into each event handler, which is
//! what lets the same core run in virtual and wall time. Keeping the two
//! impls in this dedicated module makes the boundary machine-checkable:
//! `cargo xtask lint` (rule `clock-purity`, see `rust/CONTRACTS.md`)
//! forbids `Instant`/`SystemTime` everywhere in the coordinator except
//! here and the realtime driver itself.

use std::time::Instant;

/// Source of "now" in seconds since run start. The core never reads time
/// itself — drivers sample their clock and pass the value into each event,
/// which is what lets the same core run in virtual and wall time.
pub trait Clock {
    fn now(&self) -> f64;
}

/// Wallclock seconds since an anchor instant (realtime driver).
#[derive(Debug, Clone, Copy)]
pub struct WallClock {
    t0: Instant,
}

impl WallClock {
    pub fn new(t0: Instant) -> WallClock {
        WallClock { t0 }
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

/// Virtual time set explicitly by the event loop (DES driver).
#[derive(Debug, Default)]
pub struct VirtualClock {
    t: std::cell::Cell<f64>,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    pub fn set(&self, t: f64) {
        self.t.set(t);
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.t.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_reads_what_was_set() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.set(1.25);
        assert_eq!(c.now(), 1.25);
    }

    #[test]
    fn wall_clock_is_monotonic_from_anchor() {
        let c = WallClock::new(Instant::now());
        let a = c.now();
        let b = c.now();
        assert!(a >= 0.0);
        assert!(b >= a);
    }
}
