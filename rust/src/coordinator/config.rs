//! Experiment configuration: everything a run of the MDI-Exit system needs.

use anyhow::{bail, Result};

use crate::cluster::{ClusterConfig, ScoreWeights};
use crate::policy::{AdaptConfig, PolicyConfig};
use crate::routing::{Placement, SourceSpec};
use crate::sched::{CoalesceMode, DisciplineKind, SchedConfig};
use crate::simnet::{ChurnEvent, LinkSpec};
use crate::telemetry::TelemetryConfig;
use crate::util::toml::{Config as Toml, Value};
use crate::workload::{ArrivalSpec, WorkloadConfig};

/// How the source admits data (paper §IV.B — the two scenarios).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmissionMode {
    /// Scenario (i), Figs 3–4: the confidence threshold is fixed; Alg. 3
    /// adapts the interarrival time μ. `initial_mu_s` seeds the controller.
    AdaptiveRate { threshold: f32, initial_mu_s: f64 },
    /// Scenario (ii), Figs 5–6: Poisson arrivals at a fixed mean rate; Alg. 4
    /// adapts the early-exit threshold T_e (hence accuracy).
    AdaptiveThreshold { rate_hz: f64, initial_t_e: f32, t_e_min: f32 },
    /// Open-loop: fixed deterministic rate and fixed threshold (ablations,
    /// latency microbenchmarks).
    Fixed { rate_hz: f64, threshold: f32 },
}

/// System-level execution baseline (DESIGN.md §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// The paper's system: model-distributed + early-exit (per config).
    MdiExit,
    /// Data-distributed inference baseline: whole images round-robin to
    /// workers, each running the entire model (no partition, no exits).
    Ddi,
}

/// Full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Model name in the manifest ("mobilenetv2l" / "resnetl").
    pub model: String,
    /// Topology name (`simnet::Topology::named`).
    pub topology: String,
    /// Run the autoencoder on the stage-1 boundary (resnetl, Fig. 6).
    pub use_ae: bool,
    /// Disable early exits (No-EE baselines): only the final exit fires.
    pub no_early_exit: bool,
    pub mode: Mode,
    pub admission: AdmissionMode,
    /// Alg. 3/4 shared constants (paper §V values by default).
    pub adapt: AdaptConfig,
    /// Output-queue threshold T_O of Alg. 1 (paper: 50).
    pub t_o: usize,
    /// Which exit/offload/adaptation policies the workers run
    /// (`crate::policy`). The default — Alg. 1 + Alg. 2 + AIMD — is the
    /// paper, bit for bit. TOML `[policy]`, CLI
    /// `--exit-policy`/`--offload-policy`.
    pub policy: PolicyConfig,
    pub link: LinkSpec,
    /// Virtual (DES) or wallclock (realtime) seconds to run *after* warmup.
    pub duration_s: f64,
    /// Settling period excluded from the measured statistics.
    pub warmup_s: f64,
    /// Neighbor-state gossip period (paper: workers "periodically learn").
    pub gossip_interval_s: f64,
    /// Global compute scale: stage costs are divided by this (1.0 = the
    /// build machine's measured costs; <1 models slower edge devices).
    pub compute_scale: f64,
    /// WiFi shared-medium contention: effective link bandwidth is divided
    /// by `1 + contention · concurrent_transfers`. 0 = independent links
    /// (switched network); 1 = fully shared medium like the paper's WiFi.
    /// This is what makes the 5-node mesh transmission-bottlenecked in
    /// Fig. 5 and rescued by the autoencoder in Fig. 6.
    pub medium_contention: f64,
    /// Worker join/leave schedule (paper §III: "workers join and leave the
    /// system anytime"). Applied on top of the named topology.
    pub churn: Vec<ChurnEvent>,
    /// Queue discipline / traffic classes / batching (`crate::sched`).
    /// The default (FIFO, one class, batch 1) reproduces the seed system.
    pub sched: SchedConfig,
    /// Which nodes admit data and at what per-source rate share
    /// (`crate::routing`). The default — a single source at node 0 —
    /// reproduces the paper's setup; structural fit against the topology
    /// is checked by the drivers, which know the node count.
    pub placement: Placement,
    /// Traffic arrival process per source (`crate::workload`). The default
    /// ([`ArrivalSpec::Legacy`]) keeps the admission mode's own pacing and
    /// reproduces seed behavior bit for bit. TOML `[workload]`, CLI
    /// `--arrival`.
    pub workload: WorkloadConfig,
    /// Ride gossip summaries on task/result envelopes already headed to the
    /// same neighbor instead of always minting dedicated `State` envelopes.
    /// Off by default: piggybacking changes wire-byte totals and therefore
    /// the link-jitter draw order, so the seed wire stays bit-for-bit.
    pub gossip_piggyback: bool,
    /// Observability: trace spans, metrics cadence, flight recorder
    /// (`crate::telemetry`). Default: everything off — the cores carry no
    /// recorder and the hot path stays byte-identical to the seed. TOML
    /// `[telemetry]`, CLI `--trace`/`--metrics`/`--metrics-interval`.
    pub telemetry: TelemetryConfig,
    /// Elastic fleet control plane: heartbeat health checking, occupancy
    /// autoscaling, live re-layering (`crate::cluster`). Default: disabled —
    /// no beats ride gossip and the seed wire accounting stays bit-for-bit.
    /// TOML `[cluster]`, CLI `--cluster` plus `--cluster-*` knobs.
    pub cluster: ClusterConfig,
    pub seed: u64,
}

impl ExperimentConfig {
    /// Paper §V defaults: T_Q1=10, T_Q2=30, T_O=50, α=.2, β=.1, ζ=.2.
    pub fn new(model: &str, topology: &str, admission: AdmissionMode) -> ExperimentConfig {
        ExperimentConfig {
            model: model.to_string(),
            topology: topology.to_string(),
            use_ae: false,
            no_early_exit: false,
            mode: Mode::MdiExit,
            admission,
            adapt: AdaptConfig::default(),
            t_o: 50,
            policy: PolicyConfig::default(),
            link: LinkSpec::wifi(),
            duration_s: 60.0,
            warmup_s: 10.0,
            gossip_interval_s: 0.1,
            compute_scale: 1.0,
            medium_contention: 1.0,
            churn: Vec::new(),
            sched: SchedConfig::default(),
            placement: Placement::default(),
            workload: WorkloadConfig::default(),
            gossip_piggyback: false,
            telemetry: TelemetryConfig::default(),
            cluster: ClusterConfig::default(),
            seed: 7,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if let Err(e) = self.adapt.validate() {
            bail!("adapt config: {e}");
        }
        match self.admission {
            AdmissionMode::AdaptiveRate { threshold, initial_mu_s } => {
                if !(0.0..=1.0).contains(&(threshold as f64)) {
                    bail!("threshold {threshold} outside [0,1]");
                }
                if initial_mu_s <= 0.0 {
                    bail!("initial_mu_s must be positive");
                }
            }
            AdmissionMode::AdaptiveThreshold { rate_hz, initial_t_e, t_e_min } => {
                if rate_hz <= 0.0 {
                    bail!("rate_hz must be positive");
                }
                if t_e_min <= 0.0 {
                    bail!("paper requires T_e^min > 0");
                }
                if initial_t_e < t_e_min || initial_t_e > 1.0 {
                    bail!("initial_t_e {initial_t_e} outside [{t_e_min}, 1]");
                }
            }
            AdmissionMode::Fixed { rate_hz, .. } => {
                if rate_hz <= 0.0 {
                    bail!("rate_hz must be positive");
                }
            }
        }
        if self.duration_s <= 0.0 || self.warmup_s < 0.0 {
            bail!("bad duration/warmup");
        }
        if self.gossip_interval_s <= 0.0 {
            bail!("gossip interval must be positive");
        }
        if self.compute_scale <= 0.0 {
            bail!("compute_scale must be positive");
        }
        if self.medium_contention < 0.0 {
            bail!("medium_contention must be non-negative");
        }
        if let Err(e) = self.sched.validate() {
            bail!("sched config: {e}");
        }
        if self.placement.sources.is_empty() {
            bail!("placement declares no sources");
        }
        if let Err(e) = self.workload.validate() {
            bail!("workload config: {e}");
        }
        if let Err(e) = self.telemetry.validate() {
            bail!("telemetry config: {e}");
        }
        if let Err(e) = self.cluster.validate() {
            bail!("cluster config: {e}");
        }
        Ok(())
    }

    /// Build from a TOML-subset config file (CLI `run --config`).
    ///
    /// Uses the checked accessors throughout: a key that is present with
    /// the wrong type (`seed = "7"`) is an error naming the key, never a
    /// silent fallback to the default.
    pub fn from_toml(toml: &Toml) -> Result<ExperimentConfig> {
        let model = toml.try_str("model")?.unwrap_or("mobilenetv2l");
        let topology = toml.try_str("topology")?.unwrap_or("3-node-mesh");
        let mode = toml.try_str("admission.mode")?.unwrap_or("adaptive-rate");
        let admission = match mode {
            "adaptive-rate" => AdmissionMode::AdaptiveRate {
                threshold: toml.try_f64("admission.threshold")?.unwrap_or(0.8) as f32,
                initial_mu_s: toml.try_f64("admission.initial_mu_s")?.unwrap_or(0.5),
            },
            "adaptive-threshold" => AdmissionMode::AdaptiveThreshold {
                rate_hz: toml.try_f64("admission.rate_hz")?.unwrap_or(20.0),
                initial_t_e: toml.try_f64("admission.initial_t_e")?.unwrap_or(0.8) as f32,
                t_e_min: toml.try_f64("admission.t_e_min")?.unwrap_or(0.05) as f32,
            },
            "fixed" => AdmissionMode::Fixed {
                rate_hz: toml.try_f64("admission.rate_hz")?.unwrap_or(20.0),
                threshold: toml.try_f64("admission.threshold")?.unwrap_or(0.8) as f32,
            },
            other => bail!("unknown admission.mode {other:?}"),
        };
        let mut cfg = ExperimentConfig::new(model, topology, admission);
        cfg.use_ae = toml.try_bool("use_ae")?.unwrap_or(false);
        cfg.no_early_exit = toml.try_bool("no_early_exit")?.unwrap_or(false);
        cfg.mode = match toml.try_str("system_mode")?.unwrap_or("mdi-exit") {
            "mdi-exit" => Mode::MdiExit,
            "ddi" => Mode::Ddi,
            other => bail!("unknown system_mode {other:?}"),
        };
        cfg.adapt = AdaptConfig {
            t_q1: toml.try_usize("adapt.t_q1")?.unwrap_or(10),
            t_q2: toml.try_usize("adapt.t_q2")?.unwrap_or(30),
            alpha: toml.try_f64("adapt.alpha")?.unwrap_or(0.2),
            beta: toml.try_f64("adapt.beta")?.unwrap_or(0.1),
            zeta: toml.try_f64("adapt.zeta")?.unwrap_or(0.2),
            sleep_s: toml.try_f64("adapt.sleep_s")?.unwrap_or(0.5),
        };
        cfg.t_o = toml.try_usize("t_o")?.unwrap_or(50);
        cfg.policy = Self::policy_from_toml(toml)?;
        cfg.link = LinkSpec {
            bandwidth_bps: toml.try_f64("net.bandwidth_mbps")?.unwrap_or(48.0) * 1e6 / 8.0,
            base_latency_s: toml.try_f64("net.base_latency_ms")?.unwrap_or(3.0) / 1e3,
            jitter_s: toml.try_f64("net.jitter_ms")?.unwrap_or(1.0) / 1e3,
        };
        cfg.duration_s = toml.try_f64("duration_s")?.unwrap_or(60.0);
        cfg.warmup_s = toml.try_f64("warmup_s")?.unwrap_or(10.0);
        cfg.gossip_interval_s = toml.try_f64("gossip_interval_s")?.unwrap_or(0.1);
        cfg.compute_scale = toml.try_f64("compute_scale")?.unwrap_or(1.0);
        cfg.medium_contention = toml.try_f64("net.medium_contention")?.unwrap_or(1.0);
        cfg.sched = Self::sched_from_toml(toml)?;
        cfg.placement = Self::placement_from_toml(toml)?;
        cfg.workload = Self::workload_from_toml(toml)?;
        cfg.gossip_piggyback = toml.try_bool("gossip_piggyback")?.unwrap_or(false);
        cfg.telemetry = Self::telemetry_from_toml(toml)?;
        cfg.cluster = Self::cluster_from_toml(toml)?;
        cfg.seed = toml.try_i64("seed")?.unwrap_or(7) as u64;
        cfg.validate()?;
        Ok(cfg)
    }

    /// `[policy]` section (plus the legacy top-level `offload_policy` key,
    /// which older configs used for the Alg. 2 ablation family):
    ///
    /// ```toml
    /// [policy]
    /// exit = "alg1"              # alg1 | local-only
    /// offload = "deadline-aware" # alg2 | deterministic | queue-only |
    ///                            # round-robin | deadline-aware | multi-hop
    /// adapt = "aimd"
    /// ```
    fn policy_from_toml(toml: &Toml) -> Result<PolicyConfig> {
        let mut policy = PolicyConfig::default();
        // Legacy spelling first, so `[policy] offload` wins when both are
        // present.
        if let Some(v) = toml.get("offload_policy") {
            match v.as_str() {
                Some(name) => policy.offload = PolicyConfig::parse_offload(name)?,
                None => bail!("offload_policy must be a string"),
            }
        }
        if let Some(v) = toml.get("policy.exit") {
            match v.as_str() {
                Some(name) => policy.exit = PolicyConfig::parse_exit(name)?,
                None => bail!("policy.exit must be a string"),
            }
        }
        if let Some(v) = toml.get("policy.offload") {
            match v.as_str() {
                Some(name) => policy.offload = PolicyConfig::parse_offload(name)?,
                None => bail!("policy.offload must be a string"),
            }
        }
        if let Some(v) = toml.get("policy.adapt") {
            match v.as_str() {
                Some(name) => policy.adapt = PolicyConfig::parse_adapt(name)?,
                None => bail!("policy.adapt must be a string"),
            }
        }
        Ok(policy)
    }

    /// `[placement]` section: source nodes and optional per-source rate
    /// shares.
    ///
    /// ```toml
    /// [placement]
    /// sources = [0, 3]
    /// rate_shares = [1.0, 0.5]   # optional; defaults to 1.0 each
    /// ```
    fn placement_from_toml(toml: &Toml) -> Result<Placement> {
        let nodes: Vec<usize> = match toml.get("placement.sources") {
            None => return Ok(Placement::default()),
            Some(Value::Arr(vs)) => {
                let ns: Option<Vec<i64>> = vs.iter().map(|v| v.as_i64()).collect();
                match ns {
                    Some(ns) if ns.iter().all(|&n| n >= 0) => {
                        ns.into_iter().map(|n| n as usize).collect()
                    }
                    _ => bail!("placement.sources entries must be non-negative integers"),
                }
            }
            Some(v) => match v.as_i64() {
                Some(n) if n >= 0 => vec![n as usize],
                _ => bail!("placement.sources must be a node id or an array of them"),
            },
        };
        let shares: Vec<f64> = match toml.get("placement.rate_shares") {
            None => vec![1.0; nodes.len()],
            Some(Value::Arr(vs)) => {
                let ss: Option<Vec<f64>> = vs.iter().map(|v| v.as_f64()).collect();
                let ss = match ss {
                    Some(ss) => ss,
                    None => bail!("placement.rate_shares entries must be numbers"),
                };
                if ss.len() != nodes.len() {
                    bail!(
                        "placement.rate_shares has {} entries for {} sources",
                        ss.len(),
                        nodes.len()
                    );
                }
                ss
            }
            Some(v) => match v.as_f64() {
                Some(s) => vec![s; nodes.len()],
                None => bail!("placement.rate_shares must be a number or array"),
            },
        };
        Ok(Placement {
            sources: nodes
                .into_iter()
                .zip(shares)
                .map(|(node, rate_share)| SourceSpec { node, rate_share })
                .collect(),
        })
    }

    /// `[sched]` section: discipline, classes, deadline budgets, batching.
    fn sched_from_toml(toml: &Toml) -> Result<SchedConfig> {
        let discipline = match toml.try_str("sched.discipline")?.unwrap_or("fifo") {
            "fifo" => DisciplineKind::Fifo,
            "strict-priority" | "priority" => DisciplineKind::StrictPriority,
            "edf" => DisciplineKind::Edf {
                drop_late: toml.try_bool("sched.drop_late")?.unwrap_or(false),
            },
            "drr" | "weighted-fair" => DisciplineKind::WeightedFair,
            other => bail!("unknown sched.discipline {other:?}"),
        };
        let classes = toml.try_i64("sched.num_classes")?.unwrap_or(1);
        if !(1..=255).contains(&classes) {
            bail!("sched.num_classes {classes} outside 1..=255");
        }
        let mut sched =
            SchedConfig { discipline, ..SchedConfig::default() }.with_classes(classes as u8);
        // Deadline budget: a scalar broadcasts to every class; an array
        // gives one budget per class.
        match toml.get("sched.class_deadline_s") {
            None => {}
            Some(Value::Arr(vs)) => {
                let ds: Option<Vec<f64>> = vs.iter().map(|v| v.as_f64()).collect();
                let ds = match ds {
                    Some(ds) => ds,
                    None => bail!("sched.class_deadline_s entries must be numbers"),
                };
                if ds.len() != sched.num_classes as usize {
                    bail!(
                        "sched.class_deadline_s has {} entries for {} classes",
                        ds.len(),
                        sched.num_classes
                    );
                }
                sched.class_deadline_s = ds;
            }
            Some(v) => match v.as_f64() {
                Some(d) => sched.class_deadline_s = vec![d; sched.num_classes as usize],
                None => bail!("sched.class_deadline_s must be a number or array"),
            },
        }
        // DRR quantum: a scalar broadcasts; an array gives one per class.
        match toml.get("sched.class_quantum") {
            None => {}
            Some(Value::Arr(vs)) => {
                let qs: Option<Vec<f64>> = vs.iter().map(|v| v.as_f64()).collect();
                let qs = match qs {
                    Some(qs) => qs,
                    None => bail!("sched.class_quantum entries must be numbers"),
                };
                if qs.len() != sched.num_classes as usize {
                    bail!(
                        "sched.class_quantum has {} entries for {} classes",
                        qs.len(),
                        sched.num_classes
                    );
                }
                sched.class_quantum = qs;
            }
            Some(v) => match v.as_f64() {
                Some(q) => sched.class_quantum = vec![q; sched.num_classes as usize],
                None => bail!("sched.class_quantum must be a number or array"),
            },
        }
        sched.batch.max_batch = toml.try_usize("sched.max_batch")?.unwrap_or(1);
        sched.batch.marginal =
            toml.try_f64("sched.batch_marginal")?.unwrap_or(sched.batch.marginal);
        // Cross-worker batch coalescing: whether offloads drain same-stage
        // runs into one wire envelope ("off" reproduces the seed's
        // one-task-per-message wire bit for bit).
        sched.coalesce = CoalesceMode::parse(toml.try_str("sched.coalesce")?.unwrap_or("off"))
            .map_err(|e| anyhow::anyhow!("sched.coalesce: {e}"))?;
        sched.coalesce_max = toml.try_usize("sched.coalesce_max")?.unwrap_or(sched.coalesce_max);
        Ok(sched)
    }

    /// `[telemetry]` section: observability knobs (`crate::telemetry`;
    /// validated with the rest of the config).
    ///
    /// ```toml
    /// [telemetry]
    /// trace = true        # per-task spans (Chrome trace export)
    /// metrics = true      # time-series sampling
    /// interval = 0.25     # metrics cadence in seconds
    /// flight_capacity = 64
    /// ```
    fn telemetry_from_toml(toml: &Toml) -> Result<TelemetryConfig> {
        let d = TelemetryConfig::default();
        Ok(TelemetryConfig {
            spans: toml.try_bool("telemetry.trace")?.unwrap_or(false),
            metrics: toml.try_bool("telemetry.metrics")?.unwrap_or(false),
            interval_s: toml.try_f64("telemetry.interval")?.unwrap_or(d.interval_s),
            flight_capacity: toml
                .try_usize("telemetry.flight_capacity")?
                .unwrap_or(d.flight_capacity),
            ..d
        })
    }

    /// `[cluster]` section: the elastic fleet control plane
    /// (`crate::cluster`; validated with the rest of the config).
    ///
    /// ```toml
    /// [cluster]
    /// enabled = true
    /// check_interval_s = 0.5    # controller health/load sweep cadence
    /// timeout_beats = 3.0       # missed-beat death threshold
    /// jitter_frac = 0.2         # per-peer deadline slack in [0, 1)
    /// scale_up_occupancy = 3.0  # mean queued tasks/worker to grow at
    /// scale_down_occupancy = 0.5
    /// cooldown_s = 1.0          # minimum gap between load decisions
    /// min_workers = 1
    /// max_workers = 6
    /// initial_workers = 2       # optional: park the rest at t = 0
    /// weight_cpu = 50.0         # retirement score: cpu / queue / link
    /// weight_queue = 1.0
    /// weight_link = 20.0
    /// ```
    fn cluster_from_toml(toml: &Toml) -> Result<ClusterConfig> {
        let d = ClusterConfig::default();
        Ok(ClusterConfig {
            enabled: toml.try_bool("cluster.enabled")?.unwrap_or(false),
            check_interval_s: toml.try_f64("cluster.check_interval_s")?.unwrap_or(d.check_interval_s),
            timeout_beats: toml.try_f64("cluster.timeout_beats")?.unwrap_or(d.timeout_beats),
            jitter_frac: toml.try_f64("cluster.jitter_frac")?.unwrap_or(d.jitter_frac),
            weights: ScoreWeights {
                cpu: toml.try_f64("cluster.weight_cpu")?.unwrap_or(d.weights.cpu),
                queue: toml.try_f64("cluster.weight_queue")?.unwrap_or(d.weights.queue),
                link: toml.try_f64("cluster.weight_link")?.unwrap_or(d.weights.link),
            },
            scale_up_occupancy: toml
                .try_f64("cluster.scale_up_occupancy")?
                .unwrap_or(d.scale_up_occupancy),
            scale_down_occupancy: toml
                .try_f64("cluster.scale_down_occupancy")?
                .unwrap_or(d.scale_down_occupancy),
            cooldown_s: toml.try_f64("cluster.cooldown_s")?.unwrap_or(d.cooldown_s),
            min_workers: toml.try_usize("cluster.min_workers")?.unwrap_or(d.min_workers),
            max_workers: toml.try_usize("cluster.max_workers")?.unwrap_or(d.max_workers),
            initial_workers: toml.try_usize("cluster.initial_workers")?,
        })
    }

    /// `[workload]` section: the arrival process each source runs
    /// (`crate::workload`; validated there). `[workload.sources.N]`
    /// sub-tables give individual sources their own spec — sources without
    /// one run the shared `[workload]` spec.
    ///
    /// ```toml
    /// [workload]
    /// arrival = "flash-crowd"   # legacy | constant | poisson |
    ///                           # flash-crowd | diurnal | trace
    /// peak_mult = 8.0           # flash-crowd rate multiplier at the crest
    /// flash_at_s = 30.0         # flash-crowd ramp start
    /// flash_ramp_s = 5.0        # flash-crowd ramp up (and back down) time
    /// period_s = 60.0           # diurnal cycle length
    /// depth = 0.5               # diurnal modulation depth in [0, 1)
    /// trace = "gaps.txt"        # interarrival trace for arrival = "trace"
    ///
    /// [workload.sources.3]      # node 3 only: its own mix
    /// arrival = "poisson"
    /// ```
    fn workload_from_toml(toml: &Toml) -> Result<WorkloadConfig> {
        let shared = toml.try_str("workload.arrival")?.unwrap_or("legacy");
        let arrival = Self::arrival_from_toml(toml, "workload.", shared)?;
        // Discover `[workload.sources.N]` sub-tables by key prefix (the
        // flat dotted-path store has no table nesting to walk).
        let mut nodes: Vec<usize> = Vec::new();
        for key in toml.keys() {
            let Some(rest) = key.strip_prefix("workload.sources.") else { continue };
            let Some((id, _)) = rest.split_once('.') else {
                bail!("workload.sources entries must be tables ([workload.sources.N]): {key:?}");
            };
            match id.parse::<usize>() {
                Ok(n) if !nodes.contains(&n) => nodes.push(n),
                Ok(_) => {}
                Err(_) => bail!("workload.sources.{id}: source id must be a non-negative integer"),
            }
        }
        nodes.sort_unstable();
        let mut sources = Vec::with_capacity(nodes.len());
        for n in nodes {
            let prefix = format!("workload.sources.{n}.");
            let name = match toml.try_str(&format!("{prefix}arrival"))? {
                Some(name) => name,
                None => bail!("[workload.sources.{n}] needs an arrival = \"...\" key"),
            };
            sources.push((n, Self::arrival_from_toml(toml, &prefix, name)?));
        }
        Ok(WorkloadConfig { arrival, sources })
    }

    /// Parse one named [`ArrivalSpec`] whose parameter keys live under
    /// `prefix` (`"workload."` for the shared spec, `"workload.sources.N."`
    /// for a per-source override).
    fn arrival_from_toml(toml: &Toml, prefix: &str, name: &str) -> Result<ArrivalSpec> {
        let key = |k: &str| format!("{prefix}{k}");
        Ok(match name {
            "legacy" => ArrivalSpec::Legacy,
            "constant" => ArrivalSpec::Constant,
            "poisson" => ArrivalSpec::Poisson,
            "flash-crowd" => ArrivalSpec::FlashCrowd {
                peak_mult: toml.try_f64(&key("peak_mult"))?.unwrap_or(8.0),
                at_s: toml.try_f64(&key("flash_at_s"))?.unwrap_or(30.0),
                ramp_s: toml.try_f64(&key("flash_ramp_s"))?.unwrap_or(5.0),
            },
            "diurnal" => ArrivalSpec::Diurnal {
                period_s: toml.try_f64(&key("period_s"))?.unwrap_or(60.0),
                depth: toml.try_f64(&key("depth"))?.unwrap_or(0.5),
            },
            "trace" => match toml.get(&key("trace")).and_then(|v| v.as_str()) {
                Some(path) => ArrivalSpec::trace_from_file(path)?,
                None => bail!("{prefix}arrival = \"trace\" needs {prefix}trace = \"PATH\""),
            },
            other => bail!("unknown {prefix}arrival {other:?}"),
        })
    }

    /// The fixed threshold in effect, if the mode has one.
    pub fn fixed_threshold(&self) -> Option<f32> {
        match self.admission {
            AdmissionMode::AdaptiveRate { threshold, .. } => Some(threshold),
            AdmissionMode::Fixed { threshold, .. } => Some(threshold),
            AdmissionMode::AdaptiveThreshold { .. } => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_constants() {
        let c = ExperimentConfig::new(
            "mobilenetv2l",
            "3-node-mesh",
            AdmissionMode::AdaptiveRate { threshold: 0.8, initial_mu_s: 0.5 },
        );
        assert_eq!(c.adapt.t_q1, 10);
        assert_eq!(c.adapt.t_q2, 30);
        assert_eq!(c.t_o, 50);
        assert!((c.adapt.alpha - 0.2).abs() < 1e-12);
        assert!((c.adapt.beta - 0.1).abs() < 1e-12);
        assert!((c.adapt.zeta - 0.2).abs() < 1e-12);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn validation_rejects_bad_admission() {
        let mut c = ExperimentConfig::new(
            "m",
            "local",
            AdmissionMode::AdaptiveThreshold { rate_hz: 10.0, initial_t_e: 0.5, t_e_min: 0.0 },
        );
        assert!(c.validate().is_err()); // t_e_min must be > 0
        c.admission = AdmissionMode::Fixed { rate_hz: -1.0, threshold: 0.5 };
        assert!(c.validate().is_err());
        c.admission = AdmissionMode::AdaptiveRate { threshold: 1.5, initial_mu_s: 0.5 };
        assert!(c.validate().is_err());
    }

    #[test]
    fn from_toml_roundtrip() {
        let toml = Toml::parse(
            r#"
model = "resnetl"
topology = "5-node-mesh"
use_ae = true
[admission]
mode = "adaptive-threshold"
rate_hz = 25.0
initial_t_e = 0.9
t_e_min = 0.05
[adapt]
sleep_s = 0.25
[net]
bandwidth_mbps = 24.0
"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&toml).unwrap();
        assert_eq!(c.model, "resnetl");
        assert!(c.use_ae);
        assert!(matches!(c.admission, AdmissionMode::AdaptiveThreshold { .. }));
        assert!((c.adapt.sleep_s - 0.25).abs() < 1e-12);
        assert!((c.link.bandwidth_bps - 3.0e6).abs() < 1.0);
    }

    #[test]
    fn from_toml_rejects_unknown_enum() {
        let toml = Toml::parse("[admission]\nmode = \"warp-drive\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&toml).is_err());
    }

    #[test]
    fn from_toml_wrong_typed_key_errors_with_key_name() {
        // A mistyped value must not silently fall back to the default.
        for (src, key) in [
            ("seed = \"seven\"\n", "seed"),
            ("[admission]\nmode = \"fixed\"\nrate_hz = \"fast\"\n", "admission.rate_hz"),
            ("duration_s = \"long\"\n", "duration_s"),
            ("[adapt]\nt_q1 = -4\n", "adapt.t_q1"),
            ("[sched]\nmax_batch = \"big\"\n", "sched.max_batch"),
            ("[telemetry]\ntrace = \"yes\"\n", "telemetry.trace"),
            ("[cluster]\nenabled = \"yes\"\n", "cluster.enabled"),
            ("[cluster]\nenabled = true\nmax_workers = -2\n", "cluster.max_workers"),
            ("[workload]\narrival = \"diurnal\"\ndepth = \"deep\"\n", "workload.depth"),
            ("use_ae = 1\n", "use_ae"),
        ] {
            let toml = Toml::parse(src).unwrap();
            let err = ExperimentConfig::from_toml(&toml)
                .expect_err(&format!("{src:?} should fail"))
                .to_string();
            assert!(err.contains(key), "error {err:?} should name `{key}`");
        }
    }

    #[test]
    fn from_toml_defaults_to_seed_scheduling() {
        let toml = Toml::parse("model = \"tiny\"\n").unwrap();
        let c = ExperimentConfig::from_toml(&toml).unwrap();
        assert_eq!(c.sched, SchedConfig::default());
    }

    #[test]
    fn from_toml_parses_sched_section() {
        let toml = Toml::parse(
            r#"
[sched]
discipline = "strict-priority"
num_classes = 3
class_deadline_s = [0.1, 0.5, 2.0]
max_batch = 8
batch_marginal = 0.1
"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&toml).unwrap();
        assert_eq!(c.sched.discipline, DisciplineKind::StrictPriority);
        assert_eq!(c.sched.num_classes, 3);
        assert_eq!(c.sched.class_deadline_s, vec![0.1, 0.5, 2.0]);
        assert_eq!(c.sched.batch.max_batch, 8);
        assert!((c.sched.batch.marginal - 0.1).abs() < 1e-12);
    }

    #[test]
    fn from_toml_sched_scalar_deadline_broadcasts() {
        let toml = Toml::parse(
            "[sched]\ndiscipline = \"edf\"\ndrop_late = true\nnum_classes = 2\nclass_deadline_s = 0.25\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&toml).unwrap();
        assert_eq!(c.sched.discipline, DisciplineKind::Edf { drop_late: true });
        assert_eq!(c.sched.class_deadline_s, vec![0.25, 0.25]);
    }

    #[test]
    fn from_toml_parses_policy_section_and_legacy_key() {
        use crate::policy::{ExitKind, OffloadKind};
        // Defaults: the paper's policies.
        let c = ExperimentConfig::from_toml(&Toml::parse("model = \"tiny\"\n").unwrap()).unwrap();
        assert_eq!(c.policy, PolicyConfig::default());
        // Legacy top-level key still works.
        let c = ExperimentConfig::from_toml(
            &Toml::parse("offload_policy = \"queue-only\"\n").unwrap(),
        )
        .unwrap();
        assert_eq!(c.policy.offload, OffloadKind::QueueOnly);
        // New section, all three seams.
        let c = ExperimentConfig::from_toml(
            &Toml::parse(
                "[policy]\nexit = \"local-only\"\noffload = \"deadline-aware\"\nadapt = \"aimd\"\n",
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.policy.exit, ExitKind::LocalOnly);
        assert_eq!(c.policy.offload, OffloadKind::DeadlineAware);
        // The section wins over the legacy key.
        let c = ExperimentConfig::from_toml(
            &Toml::parse("offload_policy = \"queue-only\"\n[policy]\noffload = \"multi-hop\"\n")
                .unwrap(),
        )
        .unwrap();
        assert_eq!(c.policy.offload, OffloadKind::MultiHop);
        // Unknown names are rejected.
        assert!(ExperimentConfig::from_toml(
            &Toml::parse("[policy]\noffload = \"warp-drive\"\n").unwrap()
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            &Toml::parse("offload_policy = \"warp-drive\"\n").unwrap()
        )
        .is_err());
    }

    #[test]
    fn from_toml_parses_coalesce_knobs() {
        let toml = Toml::parse(
            "[sched]\ncoalesce = \"stage-class\"\ncoalesce_max = 16\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&toml).unwrap();
        assert_eq!(c.sched.coalesce, CoalesceMode::StageClass);
        assert_eq!(c.sched.coalesce_max, 16);
        // Default stays the seed wire.
        let c = ExperimentConfig::from_toml(&Toml::parse("model = \"tiny\"\n").unwrap())
            .unwrap();
        assert_eq!(c.sched.coalesce, CoalesceMode::Off);
        // Bad values are rejected.
        assert!(ExperimentConfig::from_toml(
            &Toml::parse("[sched]\ncoalesce = \"warp\"\n").unwrap()
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            &Toml::parse("[sched]\ncoalesce = \"stage\"\ncoalesce_max = 0\n").unwrap()
        )
        .is_err());
    }

    #[test]
    fn from_toml_parses_drr_quanta() {
        let toml = Toml::parse(
            "[sched]\ndiscipline = \"drr\"\nnum_classes = 2\nclass_quantum = [2.0, 1.0]\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&toml).unwrap();
        assert_eq!(c.sched.discipline, DisciplineKind::WeightedFair);
        assert_eq!(c.sched.class_quantum, vec![2.0, 1.0]);
        // Scalar broadcasts; bad shapes rejected.
        let toml = Toml::parse(
            "[sched]\ndiscipline = \"weighted-fair\"\nnum_classes = 3\nclass_quantum = 0.5\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&toml).unwrap();
        assert_eq!(c.sched.class_quantum, vec![0.5, 0.5, 0.5]);
        let toml =
            Toml::parse("[sched]\nnum_classes = 2\nclass_quantum = [1.0, 2.0, 3.0]\n").unwrap();
        assert!(ExperimentConfig::from_toml(&toml).is_err());
    }

    #[test]
    fn from_toml_defaults_to_single_source_zero() {
        let toml = Toml::parse("model = \"tiny\"\n").unwrap();
        let c = ExperimentConfig::from_toml(&toml).unwrap();
        assert_eq!(c.placement, Placement::single(0));
    }

    #[test]
    fn from_toml_parses_placement_section() {
        let toml = Toml::parse(
            "[placement]\nsources = [0, 3]\nrate_shares = [1.0, 0.5]\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&toml).unwrap();
        assert_eq!(c.placement.source_nodes(), vec![0, 3]);
        assert!((c.placement.rate_share(3) - 0.5).abs() < 1e-12);
        assert!((c.placement.rate_share(0) - 1.0).abs() < 1e-12);

        let toml = Toml::parse("[placement]\nsources = 2\n").unwrap();
        let c = ExperimentConfig::from_toml(&toml).unwrap();
        assert_eq!(c.placement, Placement::single(2));
    }

    #[test]
    fn from_toml_placement_rejects_bad_shapes() {
        let toml = Toml::parse("[placement]\nsources = [0, -1]\n").unwrap();
        assert!(ExperimentConfig::from_toml(&toml).is_err());
        let toml =
            Toml::parse("[placement]\nsources = [0, 1]\nrate_shares = [1.0]\n").unwrap();
        assert!(ExperimentConfig::from_toml(&toml).is_err());
        let toml = Toml::parse("[placement]\nsources = \"all\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&toml).is_err());
    }

    #[test]
    fn from_toml_defaults_to_legacy_workload() {
        let c = ExperimentConfig::from_toml(&Toml::parse("model = \"tiny\"\n").unwrap()).unwrap();
        assert_eq!(c.workload, WorkloadConfig::default());
        assert_eq!(c.workload.arrival, ArrivalSpec::Legacy);
        assert!(!c.gossip_piggyback);
    }

    #[test]
    fn from_toml_parses_workload_section() {
        let toml = Toml::parse(
            "[workload]\narrival = \"flash-crowd\"\npeak_mult = 4.0\nflash_at_s = 10.0\nflash_ramp_s = 2.0\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&toml).unwrap();
        assert_eq!(
            c.workload.arrival,
            ArrivalSpec::FlashCrowd { peak_mult: 4.0, at_s: 10.0, ramp_s: 2.0 }
        );

        let toml = Toml::parse("[workload]\narrival = \"poisson\"\n").unwrap();
        let c = ExperimentConfig::from_toml(&toml).unwrap();
        assert_eq!(c.workload.arrival, ArrivalSpec::Poisson);

        let toml = Toml::parse("gossip_piggyback = true\n").unwrap();
        let c = ExperimentConfig::from_toml(&toml).unwrap();
        assert!(c.gossip_piggyback);
    }

    #[test]
    fn from_toml_rejects_bad_workload() {
        let toml = Toml::parse("[workload]\narrival = \"warp-drive\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&toml).is_err());
        // Bad parameters fail validation at the end of from_toml.
        let toml = Toml::parse("[workload]\narrival = \"diurnal\"\ndepth = 2.0\n").unwrap();
        assert!(ExperimentConfig::from_toml(&toml).is_err());
        // trace mode needs a path.
        let toml = Toml::parse("[workload]\narrival = \"trace\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&toml).is_err());
    }

    #[test]
    fn from_toml_parses_telemetry_section() {
        let toml = Toml::parse(
            "[telemetry]\ntrace = true\nmetrics = true\ninterval = 0.5\nflight_capacity = 16\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&toml).unwrap();
        assert!(c.telemetry.spans);
        assert!(c.telemetry.metrics);
        assert!((c.telemetry.interval_s - 0.5).abs() < 1e-12);
        assert_eq!(c.telemetry.flight_capacity, 16);
        assert!(c.telemetry.enabled());
        // Default: fully off.
        let c = ExperimentConfig::from_toml(&Toml::parse("model = \"tiny\"\n").unwrap()).unwrap();
        assert!(!c.telemetry.enabled());
        // Bad cadence fails validation.
        let toml = Toml::parse("[telemetry]\nmetrics = true\ninterval = 0.0\n").unwrap();
        assert!(ExperimentConfig::from_toml(&toml).is_err());
    }

    #[test]
    fn from_toml_parses_cluster_section() {
        let toml = Toml::parse(
            "[cluster]\nenabled = true\ncheck_interval_s = 0.25\ntimeout_beats = 4.0\n\
             scale_up_occupancy = 2.0\nmin_workers = 2\nmax_workers = 5\n\
             initial_workers = 3\nweight_cpu = 10.0\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&toml).unwrap();
        assert!(c.cluster.enabled);
        assert!((c.cluster.check_interval_s - 0.25).abs() < 1e-12);
        assert!((c.cluster.timeout_beats - 4.0).abs() < 1e-12);
        assert!((c.cluster.scale_up_occupancy - 2.0).abs() < 1e-12);
        assert_eq!(c.cluster.min_workers, 2);
        assert_eq!(c.cluster.max_workers, 5);
        assert_eq!(c.cluster.initial_workers, Some(3));
        assert!((c.cluster.weights.cpu - 10.0).abs() < 1e-12);
        // Unset knobs keep the documented defaults.
        let d = ClusterConfig::default();
        assert!((c.cluster.cooldown_s - d.cooldown_s).abs() < 1e-12);
        assert!((c.cluster.weights.queue - d.weights.queue).abs() < 1e-12);
        // Default: control plane off, everything else irrelevant.
        let c = ExperimentConfig::from_toml(&Toml::parse("model = \"tiny\"\n").unwrap()).unwrap();
        assert_eq!(c.cluster, ClusterConfig::default());
        assert!(!c.cluster.enabled);
        // Bad knobs fail validation once enabled.
        let toml = Toml::parse("[cluster]\nenabled = true\nmin_workers = 0\n").unwrap();
        assert!(ExperimentConfig::from_toml(&toml).is_err());
    }

    #[test]
    fn from_toml_parses_per_source_workloads() {
        let toml = Toml::parse(
            "[placement]\nsources = [0, 2, 3]\n\
             [workload]\narrival = \"poisson\"\n\
             [workload.sources.3]\narrival = \"flash-crowd\"\npeak_mult = 6.0\n\
             [workload.sources.2]\narrival = \"constant\"\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&toml).unwrap();
        assert_eq!(c.workload.arrival, ArrivalSpec::Poisson);
        assert_eq!(
            c.workload.sources,
            vec![
                (2, ArrivalSpec::Constant),
                (3, ArrivalSpec::FlashCrowd { peak_mult: 6.0, at_s: 30.0, ramp_s: 5.0 }),
            ]
        );
        // spec_for: listed sources get their mix, the rest share [workload].
        assert_eq!(*c.workload.spec_for(2), ArrivalSpec::Constant);
        assert_eq!(*c.workload.spec_for(0), ArrivalSpec::Poisson);
        // A sub-table without an arrival key is an error, not a silent
        // fallback.
        let toml = Toml::parse("[workload.sources.1]\npeak_mult = 2.0\n").unwrap();
        assert!(ExperimentConfig::from_toml(&toml).is_err());
        // Non-numeric source ids are rejected.
        let toml = Toml::parse("[workload.sources.all]\narrival = \"poisson\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&toml).is_err());
    }

    #[test]
    fn from_toml_sched_rejects_bad_shapes() {
        let toml =
            Toml::parse("[sched]\nnum_classes = 2\nclass_deadline_s = [0.1, 0.2, 0.3]\n")
                .unwrap();
        assert!(ExperimentConfig::from_toml(&toml).is_err());
        let toml = Toml::parse("[sched]\ndiscipline = \"warp-drive\"\n").unwrap();
        assert!(ExperimentConfig::from_toml(&toml).is_err());
        let toml = Toml::parse("[sched]\nnum_classes = 0\n").unwrap();
        assert!(ExperimentConfig::from_toml(&toml).is_err());
    }
}
