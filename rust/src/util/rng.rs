//! Deterministic PRNG + distributions (rand-crate substitute, DESIGN.md §1).
//!
//! PCG64 (PCG-XSL-RR 128/64) — small, fast, statistically solid, and
//! reproducible across platforms. Everything stochastic in the coordinator
//! and simnet (Poisson arrivals, link jitter, probabilistic offloading,
//! worker churn) draws from explicitly-seeded instances of this generator,
//! so every experiment is replayable from its seed.

/// The central RNG-stream registry.
///
/// Every `Pcg64::new` / `Pcg64::fork` call site in the crate must take its
/// stream argument from a constant declared here — `cargo xtask lint` (rule
/// `rng-streams`, see `rust/CONTRACTS.md`) rejects magic-number streams and
/// overlapping reservations. The registry exists so that two subsystems can
/// never silently share a (seed, stream) pair: a shared pair yields
/// correlated draws, which desynchronizes the DES and realtime drivers and
/// breaks the repo's bit-for-bit determinism property.
///
/// Conventions:
/// * A plain `FOO` constant reserves exactly one stream id.
/// * A `FOO_BASE` constant reserves the half-open range
///   `[FOO_BASE, FOO_BASE + FOO_SPAN)` and must have a sibling `FOO_SPAN`;
///   call sites index into the range (`FOO_BASE + worker_id`).
/// * Reservations are pairwise disjoint — checked both by `xtask lint`
///   (statically, over these declarations) and by the `reservations`
///   unit test below (at runtime).
/// * Values are frozen: property tests lock policy traces bit-for-bit to
///   the seed, so renumbering a stream is a determinism break. New
///   subsystems take fresh ranges above the existing ones.
pub mod streams {
    /// Realtime `DelayNet` per-link jitter: `RT_LINK_JITTER_BASE + link_id`.
    ///
    /// Historical values cap the fleet: link ids at or above
    /// [`RT_LINK_JITTER_SPAN`] would collide with [`WORKER_CORE_BASE`],
    /// so realtime runs support < 900 endpoints (far above any
    /// configuration the repo ships).
    pub const RT_LINK_JITTER_BASE: u64 = 100;
    /// Width of the [`RT_LINK_JITTER_BASE`] range.
    pub const RT_LINK_JITTER_SPAN: u64 = 900;

    /// Per-worker core decision stream: `WORKER_CORE_BASE + worker_id`
    /// (probabilistic offload, churn, policy tie-breaks).
    pub const WORKER_CORE_BASE: u64 = 1000;
    /// Width of the [`WORKER_CORE_BASE`] range.
    pub const WORKER_CORE_SPAN: u64 = 3000;

    /// `Topology::random_geometric` node placement + connectivity repair.
    pub const TOPO_GEOMETRIC: u64 = 4242;
    /// `Topology::scale_free` preferential-attachment draws.
    pub const TOPO_SCALE_FREE: u64 = 4343;

    /// DES driver link-jitter stream (single generator, forked per draw).
    pub const DES_LINK_JITTER: u64 = 7777;

    /// Per-source workload arrivals: `ARRIVAL_STREAM_BASE + source_id`.
    /// Dedicated range so arrival draws never perturb core decision
    /// streams when sources are added.
    pub const ARRIVAL_STREAM_BASE: u64 = 9000;
    /// Width of the [`ARRIVAL_STREAM_BASE`] range.
    pub const ARRIVAL_STREAM_SPAN: u64 = 1_000_000;

    /// `testkit::prop` per-case derivation stream.
    pub const PROP_CASES: u64 = 42;

    /// Per-node cluster health-checker jitter: `CLUSTER_HEALTH_BASE + id`.
    /// Dedicated range (fresh, above the arrival streams) so enabling the
    /// control plane never perturbs admission, offload, or link-jitter
    /// draws — the seed wire accounting stays bit-for-bit when the
    /// heartbeat deadline jitter is the only new randomness.
    pub const CLUSTER_HEALTH_BASE: u64 = 1_100_000;
    /// Width of the [`CLUSTER_HEALTH_BASE`] range.
    pub const CLUSTER_HEALTH_SPAN: u64 = 4096;

    /// All reservations as `(name, base, span)`; plain constants have
    /// span 1. Used by the disjointness test and kept in sync with the
    /// declarations above (xtask checks the declarations themselves).
    pub fn reservations() -> Vec<(&'static str, u64, u64)> {
        vec![
            ("RT_LINK_JITTER", RT_LINK_JITTER_BASE, RT_LINK_JITTER_SPAN),
            ("WORKER_CORE", WORKER_CORE_BASE, WORKER_CORE_SPAN),
            ("TOPO_GEOMETRIC", TOPO_GEOMETRIC, 1),
            ("TOPO_SCALE_FREE", TOPO_SCALE_FREE, 1),
            ("DES_LINK_JITTER", DES_LINK_JITTER, 1),
            ("ARRIVAL_STREAM", ARRIVAL_STREAM_BASE, ARRIVAL_STREAM_SPAN),
            ("PROP_CASES", PROP_CASES, 1),
            ("CLUSTER_HEALTH", CLUSTER_HEALTH_BASE, CLUSTER_HEALTH_SPAN),
        ]
    }
}

/// PCG-XSL-RR 128/64 generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with a stream id; (seed, stream) pairs give independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream)
    }

    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Rejection-sampled: no modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with mean `mean` (interarrival times of a Poisson process).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        // Avoid ln(0): f64() is in [0,1), so 1-f64() is in (0,1].
        -mean * (1.0 - self.f64()).ln()
    }

    /// Poisson-distributed count with the given mean.
    /// Knuth's product method for small lambda, normal approximation above.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let limit = (-lambda).exp();
            let mut prod = self.f64();
            let mut n = 0u64;
            while prod > limit {
                prod *= self.f64();
                n += 1;
            }
            n
        } else {
            let v = self.normal(lambda, lambda.sqrt());
            v.max(0.0).round() as u64
        }
    }

    /// Normal via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_separated() {
        let mut a = Pcg64::new(7, 1);
        let mut b = Pcg64::new(7, 1);
        let mut c = Pcg64::new(7, 2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn stream_reservations_are_disjoint() {
        let rs = streams::reservations();
        for (i, &(na, a, sa)) in rs.iter().enumerate() {
            assert!(sa > 0, "{na} has empty span");
            for &(nb, b, sb) in &rs[i + 1..] {
                let overlap = a < b + sb && b < a + sa;
                assert!(!overlap, "stream ranges {na} and {nb} overlap");
            }
        }
    }

    // Statistical tests draw tens of thousands of samples — far too slow
    // under Miri, and they exercise arithmetic, not memory.
    #[test]
    #[cfg_attr(miri, ignore)]
    fn uniform_mean_and_range() {
        let mut rng = Pcg64::new(1, 0);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        for _ in 0..1000 {
            let v = rng.range_f64(2.0, 3.0);
            assert!((2.0..3.0).contains(&v));
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn below_is_unbiased_and_in_range() {
        let mut rng = Pcg64::new(2, 0);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((8500..11500).contains(&c), "counts {counts:?}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn exponential_mean() {
        let mut rng = Pcg64::new(3, 0);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(0.25)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn poisson_mean_small_and_large_lambda() {
        let mut rng = Pcg64::new(4, 0);
        for &lambda in &[0.5, 4.0, 80.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| rng.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda {lambda} mean {mean}"
            );
        }
        assert_eq!(rng.poisson(0.0), 0);
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn normal_moments() {
        let mut rng = Pcg64::new(5, 0);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean {mean}");
        assert!((var - 4.0).abs() < 0.2, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(6, 0);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_independent() {
        let mut root = Pcg64::new(9, 0);
        let mut a = root.fork(1);
        let mut b = root.fork(1);
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
