//! In-tree substrate utilities.
//!
//! The build image is offline with a fixed crate cache (no serde_json /
//! rand / log / toml), so the substrates those crates would provide are
//! implemented here and tested like any other module (DESIGN.md §1).

pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod toml;
