//! Streaming statistics: running mean/variance, EWMA, percentiles,
//! histograms. Used by the coordinator's delay estimators (the paper's
//! Γ_n and D_nm measurements), the metrics pipeline, and the bench harness.

/// Welford running mean/variance.
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Exponentially-weighted moving average — the estimator workers use for
/// per-task compute delay Γ_n and link delay D_nm (paper §IV.A: workers
/// "periodically learn" these from their neighbors; the EWMA smooths the
/// noisy per-task samples while tracking time-varying resources).
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha), "alpha out of range");
        Ewma { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        });
    }

    /// Current estimate, or `default` before the first sample.
    pub fn get_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Reservoir of samples with exact percentiles (fine at bench scales).
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Samples { xs: Vec::new(), sorted: true }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    /// Fold another reservoir's samples into this one (merging per-thread
    /// or per-source measurements into run totals).
    pub fn absorb(&mut self, other: &Samples) {
        if other.xs.is_empty() {
            return;
        }
        self.xs.extend_from_slice(&other.xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let rank = (p / 100.0) * (self.xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        let frac = rank - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }
    pub fn p95(&mut self) -> f64 {
        self.percentile(95.0)
    }
    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }
}

/// Fixed-bin histogram over [lo, hi); out-of-range values clamp to edge bins.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], total: 0 }
    }

    pub fn push(&mut self, x: f64) {
        let n = self.bins.len();
        let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64)
            .clamp(0.0, (n - 1) as f64) as usize;
        self.bins[idx] += 1;
        self.total += 1;
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Fraction of mass in each bin.
    pub fn normalized(&self) -> Vec<f64> {
        if self.total == 0 {
            return vec![0.0; self.bins.len()];
        }
        self.bins.iter().map(|&b| b as f64 / self.total as f64).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_closed_form() {
        let mut r = Running::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            r.push(x);
        }
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn running_empty_is_zero() {
        let r = Running::new();
        assert_eq!(r.mean(), 0.0);
        assert_eq!(r.variance(), 0.0);
    }

    #[test]
    fn ewma_converges_and_smooths() {
        let mut e = Ewma::new(0.3);
        assert_eq!(e.get_or(1.5), 1.5);
        e.push(10.0);
        assert_eq!(e.get_or(0.0), 10.0); // first sample adopted directly
        for _ in 0..60 {
            e.push(2.0);
        }
        assert!((e.get_or(0.0) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn percentiles_exact_on_known_data() {
        let mut s = Samples::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((s.p99() - 99.01).abs() < 0.011);
    }

    #[test]
    fn histogram_bins_and_clamps() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.5, 1.5, 1.6, 9.9, -5.0, 55.0] {
            h.push(x);
        }
        assert_eq!(h.total(), 6);
        assert_eq!(h.bins()[0], 2); // 0.5 and clamped -5.0
        assert_eq!(h.bins()[1], 2);
        assert_eq!(h.bins()[9], 2); // 9.9 and clamped 55.0
        let norm = h.normalized();
        assert!((norm.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }
}
