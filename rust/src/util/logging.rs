//! Leveled logger with env filtering (log-crate substitute, DESIGN.md §1).
//!
//! `MDI_LOG=debug` (or trace/info/warn/error) selects the level; default is
//! `info`. Output goes to stderr with a monotonic timestamp so it never
//! interleaves with report JSON on stdout.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Trace = 0,
    Debug = 1,
    Info = 2,
    Warn = 3,
    Error = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "trace" => Some(Level::Trace),
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" | "warning" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Trace => "TRACE",
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
        }
    }
}

static THRESHOLD: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized
static START: OnceLock<Instant> = OnceLock::new();

fn threshold() -> u8 {
    let t = THRESHOLD.load(Ordering::Relaxed);
    if t != u8::MAX {
        return t;
    }
    let level = std::env::var("MDI_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(Level::Info);
    THRESHOLD.store(level as u8, Ordering::Relaxed);
    level as u8
}

/// Force a level programmatically (tests, CLI --log flag).
pub fn set_level(level: Level) {
    THRESHOLD.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level as u8 >= threshold()
}

pub fn log(level: Level, module: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    // Epoch is a `OnceLock`: no lock is held across the stderr write, so a
    // slow/blocked stderr can never serialize unrelated logging threads.
    let elapsed = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{elapsed:9.4}s {} {module}] {msg}", level.tag());
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Trace,
                                   module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug,
                                   module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info,
                                   module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn,
                                   module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error,
                                   module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn ordering() {
        assert!(Level::Trace < Level::Debug);
        assert!(Level::Debug < Level::Info);
        assert!(Level::Warn < Level::Error);
    }

    #[test]
    fn set_level_gates() {
        set_level(Level::Error);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
    }
}
