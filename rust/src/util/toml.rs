//! TOML-subset config parser (toml-crate substitute, DESIGN.md §1).
//!
//! Supports what experiment config files need: `[section]` /
//! `[section.sub]` headers, `key = value` with strings, integers, floats,
//! booleans, and flat arrays, plus `#` comments. Values land in a flat
//! `section.key -> Value` map with typed accessors and defaults.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    /// Human-readable type name, used in [`KeyError`] diagnostics.
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Str(_) => "string",
            Value::Int(_) => "integer",
            Value::Float(_) => "float",
            Value::Bool(_) => "boolean",
            Value::Arr(_) => "array",
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed config: flat dotted-path keys.
#[derive(Debug, Clone, Default)]
pub struct Config {
    entries: BTreeMap<String, Value>,
}

#[derive(Debug, Clone)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error on line {}: {}", self.line, self.msg)
    }
}
impl std::error::Error for TomlError {}

/// A present-but-wrong-typed key: the typed accessors (`try_*`) return
/// this instead of silently falling back to a default, so a config typo
/// like `seed = "7"` surfaces as a diagnostic naming the key rather than
/// a run that quietly used the default seed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KeyError {
    /// Dotted config path, e.g. `admission.t_q1`.
    pub key: String,
    /// What the accessor wanted, e.g. `integer`.
    pub expected: &'static str,
    /// What the config held, e.g. `string`.
    pub found: &'static str,
}

impl fmt::Display for KeyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "config key `{}`: expected {}, found {}",
            self.key, self.expected, self.found
        )
    }
}
impl std::error::Error for KeyError {}

impl Config {
    pub fn parse(src: &str) -> Result<Config, TomlError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError { line: lineno + 1, msg: msg.to_string() };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| err("unclosed section"))?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(err("empty section name"));
                }
                section = name.to_string();
                continue;
            }
            let (key, val) = line.split_once('=').ok_or_else(|| err("expected key = value"))?;
            let key = key.trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(val.trim()).map_err(|m| err(&m))?;
            let path =
                if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            entries.insert(path, value);
        }
        Ok(Config { entries })
    }

    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(Value::as_f64).unwrap_or(default)
    }
    pub fn i64_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(Value::as_i64).unwrap_or(default)
    }
    pub fn usize_or(&self, path: &str, default: usize) -> usize {
        self.get(path)
            .and_then(Value::as_i64)
            .and_then(|v| usize::try_from(v).ok())
            .unwrap_or(default)
    }
    pub fn str_or<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(Value::as_str).unwrap_or(default)
    }
    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Checked accessor: `Ok(None)` when absent, `Err(KeyError)` when
    /// present with the wrong type. The `*_or` methods above silently
    /// default on type mismatch; config-loading paths should prefer
    /// these so typos surface with the offending key in the message.
    pub fn try_f64(&self, path: &str) -> Result<Option<f64>, KeyError> {
        self.checked(path, "number (integer or float)", Value::as_f64)
    }
    pub fn try_i64(&self, path: &str) -> Result<Option<i64>, KeyError> {
        self.checked(path, "integer", Value::as_i64)
    }
    pub fn try_usize(&self, path: &str) -> Result<Option<usize>, KeyError> {
        self.checked(path, "non-negative integer", |v| {
            v.as_i64().and_then(|i| usize::try_from(i).ok())
        })
    }
    pub fn try_str(&self, path: &str) -> Result<Option<&str>, KeyError> {
        self.checked(path, "string", Value::as_str)
    }
    pub fn try_bool(&self, path: &str) -> Result<Option<bool>, KeyError> {
        self.checked(path, "boolean", Value::as_bool)
    }

    fn checked<'a, T>(
        &'a self,
        path: &str,
        expected: &'static str,
        cast: impl Fn(&'a Value) -> Option<T>,
    ) -> Result<Option<T>, KeyError> {
        match self.get(path) {
            None => Ok(None),
            Some(v) => match cast(v) {
                Some(t) => Ok(Some(t)),
                None => Err(KeyError {
                    key: path.to_string(),
                    expected,
                    found: v.type_name(),
                }),
            },
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quoted strings starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(body.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or("unterminated array")?;
        let body = body.trim();
        if body.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            split_top_level(body).iter().map(|it| parse_value(it.trim())).collect();
        return Ok(Value::Arr(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "fig3"            # inline comment
[admission]
t_q1 = 10
t_q2 = 30
alpha = 0.2
adaptive = true
[net]
topology = "3-node-mesh"
bandwidth_mbps = [50.0, 25.0, 12.5]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("name", ""), "fig3");
        assert_eq!(c.i64_or("admission.t_q1", 0), 10);
        assert!((c.f64_or("admission.alpha", 0.0) - 0.2).abs() < 1e-12);
        assert!(c.bool_or("admission.adaptive", false));
        assert_eq!(c.str_or("net.topology", ""), "3-node-mesh");
        let arr = c.get("net.bandwidth_mbps").unwrap();
        match arr {
            Value::Arr(v) => assert_eq!(v.len(), 3),
            _ => panic!("not an array"),
        }
    }

    #[test]
    fn defaults_for_missing() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.i64_or("missing.key", 7), 7);
        assert_eq!(c.str_or("x", "dft"), "dft");
    }

    #[test]
    fn int_coerces_to_f64() {
        let c = Config::parse("x = 3").unwrap();
        assert_eq!(c.f64_or("x", 0.0), 3.0);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = Config::parse("a = 1\nbroken line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = Config::parse("[open\n").unwrap_err();
        assert_eq!(e.line, 1);
        assert!(Config::parse("k = \"unterminated\n").is_err());
    }

    #[test]
    fn checked_accessors_name_the_offending_key() {
        let c = Config::parse("seed = \"seven\"\n[net]\nbw = 5\n").unwrap();
        let e = c.try_i64("seed").unwrap_err();
        assert_eq!(e.key, "seed");
        assert_eq!(e.expected, "integer");
        assert_eq!(e.found, "string");
        assert!(e.to_string().contains("`seed`"), "{e}");
        // Present + right type, absent, and coercions still work.
        assert_eq!(c.try_i64("net.bw").unwrap(), Some(5));
        assert_eq!(c.try_f64("net.bw").unwrap(), Some(5.0));
        assert_eq!(c.try_bool("missing.key").unwrap(), None);
        // usize rejects negatives with the key in the message.
        let c = Config::parse("n = -3").unwrap();
        let e = c.try_usize("n").unwrap_err();
        assert_eq!(e.key, "n");
        assert_eq!(e.expected, "non-negative integer");
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let c = Config::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(c.str_or("tag", ""), "a#b");
    }
}
