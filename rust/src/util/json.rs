//! Minimal JSON parser/serializer (serde_json substitute — offline image,
//! DESIGN.md §1). Parses the artifact manifest and serializes run reports.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP (unneeded: manifests are ASCII). Numbers are kept as f64 plus an
//! i64 fast path for integral values.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e18 => Some(*n as i64),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `Json::Null` for missing keys or non-objects.
    pub fn get(&self, key: &str) -> &Json {
        const NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Array index access; `Json::Null` out of range.
    pub fn idx(&self, i: usize) -> &Json {
        const NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact serialization (round-trips through `parse`).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e18 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Convenience builder for report objects.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse failure with byte offset context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        s.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // re-decode UTF-8 multibyte sequence
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    if start + len > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..start + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
        assert_eq!(v.get("a").idx(0).as_i64(), Some(1));
    }

    #[test]
    fn missing_fields_are_null() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(v.get("zz").is_null());
        assert!(v.get("a").get("deep").is_null());
        assert!(v.idx(0).is_null());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s\"x"],"n":-7,"o":{"k":[]}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""café — ünïcode""#).unwrap();
        assert_eq!(v.as_str(), Some("café — ünïcode"));
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn builder_obj() {
        let v = obj(vec![("x", 1i64.into()), ("y", "z".into())]);
        assert_eq!(v.get("x").as_i64(), Some(1));
        assert_eq!(v.get("y").as_str(), Some("z"));
    }
}
