//! Figure/table reproductions (DESIGN.md §4 experiment index).
//!
//! Each paper figure has a runner that sweeps the same axes the paper
//! sweeps and returns rows ready for printing by the bench binaries or the
//! CLI. All runners execute on the DES driver + oracle engine so a full
//! sweep finishes in seconds of wallclock for minutes of virtual time.

use anyhow::Result;

use crate::artifact::Manifest;
use crate::coordinator::{AdmissionMode, ExperimentConfig, Mode, OffloadKind, Run};
use crate::simnet::LinkSpec;

/// One plotted point of a figure.
#[derive(Debug, Clone)]
pub struct FigRow {
    /// Series label as the paper legends it, e.g. "3-Node-Mesh, MDI-Exit".
    pub series: String,
    /// x-axis value (confidence threshold for Figs 3–4, arrival rate for 5–6).
    pub x: f64,
    /// Achieved data rate (samples/s completed).
    pub rate_hz: f64,
    /// Classification accuracy over completed samples.
    pub accuracy: f64,
    /// Mean end-to-end latency (s).
    pub latency_s: f64,
    /// Bytes transferred per completed sample (transmission pressure).
    pub bytes_per_sample: f64,
}

/// Sweep durations: `quick` keeps integration tests fast; benches use full.
#[derive(Debug, Clone, Copy)]
pub struct SweepOpts {
    pub duration_s: f64,
    pub warmup_s: f64,
    pub seed: u64,
    /// Stage-compute scale (<1 = slower devices than the build machine;
    /// 0.25 ≈ Jetson Nano vs desktop CPU for these models).
    pub compute_scale: f64,
}

impl SweepOpts {
    pub fn full() -> SweepOpts {
        SweepOpts { duration_s: 60.0, warmup_s: 15.0, seed: 7, compute_scale: 0.125 }
    }
    pub fn quick() -> SweepOpts {
        SweepOpts { duration_s: 12.0, warmup_s: 4.0, seed: 7, compute_scale: 0.125 }
    }
}

/// The topologies of the paper's §V, in presentation order.
pub const TOPOLOGIES: &[&str] =
    &["local", "2-node", "3-node-mesh", "3-node-circular", "5-node-mesh"];

/// Thresholds swept in Figs 3–4 (x-axis).
pub const THRESHOLDS: &[f64] = &[0.5, 0.6, 0.7, 0.8, 0.9, 0.95];

/// Poisson mean arrival rates swept in Fig. 5 (x-axis, samples/s). The top
/// of the grid is ~3x the source's τ1-bound capacity so Alg. 4 is forced
/// into the accuracy-for-rate trade the figure is about.
pub const RATES_HZ: &[f64] = &[50.0, 100.0, 200.0, 400.0, 800.0, 1600.0];

/// Rates for the ResNet sweeps (Fig. 6, abl-ae): the model is ~8x heavier,
/// and every sample's task τ1 can only run at the source, so the grid
/// brackets that ceiling instead of sailing 10x past it.
pub const RATES_HZ_RESNET: &[f64] = &[4.0, 8.0, 12.0, 16.0, 20.0, 26.0];

/// Ratio-preserving link for the ResNet experiments (DESIGN.md §1): the
/// paper's ResNet-50 ships 3.2 MB feature vectors whose WiFi transfer time
/// dwarfs a stage's compute — our Lite features are 25x smaller while
/// compute shrank only ~5x, so a 2.4 GHz-class 12 Mbps link restores the
/// paper's transfer/compute ratio (raw τ2 input: ~90 ms on the wire vs
/// ~44 ms of stage compute). MobileNet experiments keep the default
/// 100 Mbps link (its features are small in both testbeds).
pub fn resnet_link() -> LinkSpec {
    LinkSpec { bandwidth_bps: 1.5e6, base_latency_s: 2.0e-3, jitter_s: 1.0e-3 }
}

fn apply_opts(cfg: &mut ExperimentConfig, opts: &SweepOpts) {
    cfg.duration_s = opts.duration_s;
    cfg.warmup_s = opts.warmup_s;
    cfg.seed = opts.seed;
    cfg.compute_scale = opts.compute_scale;
}

fn row_from(cfg: ExperimentConfig, series: &str, x: f64, manifest: &Manifest)
    -> Result<FigRow> {
    let report = Run::builder().config(cfg).manifest(manifest).execute()?;
    Ok(FigRow {
        series: series.to_string(),
        x,
        rate_hz: report.throughput_hz(),
        accuracy: report.accuracy(),
        latency_s: if report.completed > 0 {
            // mean latency without mutating percentiles state
            report.latency.mean()
        } else {
            0.0
        },
        bytes_per_sample: if report.completed > 0 {
            report.bytes_on_wire as f64 / report.completed as f64
        } else {
            0.0
        },
    })
}

// ---------------------------------------------------------------------------
// Figs 3 & 4 — fixed confidence threshold, Alg. 3 adapts the data rate
// ---------------------------------------------------------------------------

/// Shared machinery of Figs 3 (mobilenetv2l) and 4 (resnetl): for each
/// topology and each fixed threshold, run Alg. 3 and report the achieved
/// data rate; plus the No-EE reference points the paper plots.
pub fn fig_rate_adaptation(manifest: &Manifest, model: &str, opts: SweepOpts)
    -> Result<Vec<FigRow>> {
    let link = if model == "resnetl" { Some(resnet_link()) } else { None };
    let mut rows = Vec::new();
    for &topo in TOPOLOGIES {
        for &t in THRESHOLDS {
            let mut cfg = ExperimentConfig::new(
                model,
                topo,
                AdmissionMode::AdaptiveRate { threshold: t as f32, initial_mu_s: 0.25 },
            );
            apply_opts(&mut cfg, &opts);
            if let Some(l) = link {
                cfg.link = l;
            }
            let series = series_name(topo, "MDI-Exit");
            rows.push(row_from(cfg, &series, t, manifest)?);
        }
    }
    // No-EE reference points (paper: "Local, No EE", "3-Node-Mesh, No EE",
    // "3-Node-Circular, No EE") — threshold axis is moot; x = 1.0.
    for topo in ["local", "3-node-mesh", "3-node-circular"] {
        let mut cfg = ExperimentConfig::new(
            model,
            topo,
            AdmissionMode::AdaptiveRate { threshold: 0.9, initial_mu_s: 0.25 },
        );
        cfg.no_early_exit = true;
        apply_opts(&mut cfg, &opts);
        if let Some(l) = link {
            cfg.link = l;
        }
        rows.push(row_from(cfg, &series_name(topo, "No EE"), 1.0, manifest)?);
    }
    Ok(rows)
}

/// Fig. 3: MobileNetV2, early-exit confidence threshold fixed.
pub fn fig3(manifest: &Manifest, opts: SweepOpts) -> Result<Vec<FigRow>> {
    fig_rate_adaptation(manifest, "mobilenetv2l", opts)
}

/// Fig. 4: ResNet-50, early-exit confidence threshold fixed.
pub fn fig4(manifest: &Manifest, opts: SweepOpts) -> Result<Vec<FigRow>> {
    fig_rate_adaptation(manifest, "resnetl", opts)
}

// ---------------------------------------------------------------------------
// Figs 5 & 6 — Poisson arrivals at fixed mean rate, Alg. 4 adapts T_e
// ---------------------------------------------------------------------------

/// Shared machinery of Figs 5 (mobilenetv2l, no AE) and 6 (resnetl + AE):
/// accuracy vs mean Poisson arrival rate per topology.
pub fn fig_threshold_adaptation(manifest: &Manifest, model: &str, use_ae: bool,
                                opts: SweepOpts) -> Result<Vec<FigRow>> {
    let (rates, link) = if model == "resnetl" {
        (RATES_HZ_RESNET, Some(resnet_link()))
    } else {
        (RATES_HZ, None)
    };
    let mut rows = Vec::new();
    for &topo in TOPOLOGIES {
        for &hz in rates {
            let mut cfg = ExperimentConfig::new(
                model,
                topo,
                AdmissionMode::AdaptiveThreshold {
                    rate_hz: hz,
                    initial_t_e: 0.9,
                    t_e_min: 0.05,
                },
            );
            cfg.use_ae = use_ae;
            apply_opts(&mut cfg, &opts);
            if let Some(l) = link {
                cfg.link = l;
            }
            rows.push(row_from(cfg, &series_name(topo, "MDI-Exit"), hz, manifest)?);
        }
    }
    Ok(rows)
}

/// Fig. 5: MobileNetV2, Poisson arrivals, threshold adaptation.
pub fn fig5(manifest: &Manifest, opts: SweepOpts) -> Result<Vec<FigRow>> {
    fig_threshold_adaptation(manifest, "mobilenetv2l", false, opts)
}

/// Fig. 6: ResNet-50 with the stage-1 autoencoder, Poisson arrivals.
pub fn fig6(manifest: &Manifest, opts: SweepOpts) -> Result<Vec<FigRow>> {
    fig_threshold_adaptation(manifest, "resnetl", true, opts)
}

// ---------------------------------------------------------------------------
// Ablations (DESIGN.md §4)
// ---------------------------------------------------------------------------

/// abl-ae: ResNet on the 5-node mesh with and without the autoencoder —
/// the §V claim that the AE removes the transmission bottleneck.
pub fn ablation_autoencoder(manifest: &Manifest, opts: SweepOpts) -> Result<Vec<FigRow>> {
    let mut rows = Vec::new();
    for &use_ae in &[false, true] {
        for &hz in RATES_HZ_RESNET {
            let mut cfg = ExperimentConfig::new(
                "resnetl",
                "5-node-mesh",
                AdmissionMode::AdaptiveThreshold {
                    rate_hz: hz,
                    initial_t_e: 0.9,
                    t_e_min: 0.05,
                },
            );
            cfg.use_ae = use_ae;
            apply_opts(&mut cfg, &opts);
            cfg.link = resnet_link();
            let series = if use_ae { "5-Node-Mesh, AE" } else { "5-Node-Mesh, raw features" };
            rows.push(row_from(cfg, series, hz, manifest)?);
        }
    }
    Ok(rows)
}

/// abl-offload: Alg. 2 vs its deterministic-only variant vs naive policies,
/// on the 3-node mesh under fixed load.
pub fn ablation_offload(manifest: &Manifest, opts: SweepOpts) -> Result<Vec<FigRow>> {
    let policies = [
        (OffloadKind::Alg2, "Alg2 (paper)"),
        (OffloadKind::Deterministic, "deterministic only"),
        (OffloadKind::QueueOnly, "queue-size only"),
        (OffloadKind::RoundRobin, "round-robin"),
    ];
    let mut rows = Vec::new();
    for (policy, name) in policies {
        for &hz in &[40.0, 120.0, 240.0] {
            let mut cfg = ExperimentConfig::new(
                "mobilenetv2l",
                "3-node-mesh",
                AdmissionMode::Fixed { rate_hz: hz, threshold: 0.9 },
            );
            cfg.policy.offload = policy;
            apply_opts(&mut cfg, &opts);
            rows.push(row_from(cfg, name, hz, manifest)?);
        }
    }
    Ok(rows)
}

/// abl-queue: sensitivity to the output-queue threshold T_O of Alg. 1.
pub fn ablation_thresholds(manifest: &Manifest, opts: SweepOpts) -> Result<Vec<FigRow>> {
    let mut rows = Vec::new();
    for &t_o in &[2usize, 10, 50, 200] {
        let mut cfg = ExperimentConfig::new(
            "mobilenetv2l",
            "3-node-mesh",
            AdmissionMode::AdaptiveRate { threshold: 0.9, initial_mu_s: 0.25 },
        );
        cfg.t_o = t_o;
        apply_opts(&mut cfg, &opts);
        rows.push(row_from(cfg, &format!("T_O = {t_o}"), t_o as f64, manifest)?);
    }
    Ok(rows)
}

/// DDI baseline vs MDI-Exit (the paper's §I motivation: data-distribution
/// pays full-image transmission per sample).
pub fn ddi_comparison(manifest: &Manifest, opts: SweepOpts) -> Result<Vec<FigRow>> {
    let mut rows = Vec::new();
    for (mode, name) in [(Mode::Ddi, "DDI"), (Mode::MdiExit, "MDI-Exit")] {
        for &hz in &[40.0, 120.0, 240.0] {
            let mut cfg = ExperimentConfig::new(
                "mobilenetv2l",
                "3-node-mesh",
                AdmissionMode::Fixed { rate_hz: hz, threshold: 0.9 },
            );
            cfg.mode = mode;
            apply_opts(&mut cfg, &opts);
            rows.push(row_from(cfg, name, hz, manifest)?);
        }
    }
    Ok(rows)
}

/// Paper-style series name ("3-Node-Mesh, MDI-Exit").
pub fn series_name(topo: &str, suffix: &str) -> String {
    let pretty = match topo {
        "local" => "Local",
        "2-node" => "2-Node",
        "3-node-mesh" => "3-Node-Mesh",
        "3-node-circular" => "3-Node-Circular",
        "5-node-mesh" => "5-Node-Mesh",
        other => other,
    };
    format!("{pretty}, {suffix}")
}

/// Fixed-width table printer shared by the bench binaries and the CLI.
pub fn print_rows(title: &str, xlabel: &str, rows: &[FigRow]) {
    println!("\n== {title} ==");
    println!(
        "{:<34} {:>10} {:>12} {:>10} {:>12} {:>14}",
        "series", xlabel, "rate(Hz)", "accuracy", "latency(ms)", "bytes/sample"
    );
    for r in rows {
        println!(
            "{:<34} {:>10.3} {:>12.2} {:>10.4} {:>12.2} {:>14.0}",
            r.series,
            r.x,
            r.rate_hz,
            r.accuracy,
            r.latency_s * 1e3,
            r.bytes_per_sample
        );
    }
}
