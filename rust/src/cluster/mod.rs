//! Elastic fleet control plane: heartbeats, health scoring, autoscaling,
//! and live re-layering (ROADMAP item 2 — churn promoted from a scripted
//! timeline to a closed control loop).
//!
//! The paper's MDI-Exit framework adapts *policies* to whatever devices
//! are reachable; this module adapts the *fleet itself*. Three parts ride
//! the seams the repo already has:
//!
//! * [`HealthChecker`] — missed-beat detection fed by the
//!   [`NeighborSummary`](crate::policy::NeighborSummary) gossip already on
//!   the wire. When the control plane is on, every minted summary carries
//!   a monotone heartbeat sequence number (`beat`, +8 B on the wire, only
//!   charged when stamped); the checker declares a peer dead only after
//!   `timeout_beats` expected intervals pass with no fresh beat.
//! * [`ScoreWeights`] / [`retire_candidate`] — a composite node scorer
//!   ranking workers on cpu (gossiped Γ), queue (gossiped I), and link
//!   (receiver-local transfer estimate) weights.
//! * [`Autoscaler`] — spawns or retires workers off aggregate queue
//!   occupancy, with a thrash-preventing cooldown between load-driven
//!   decisions.
//!
//! ## Events in, actions out
//!
//! The control loop is hosted by the clock-agnostic
//! [`WorkerCore`](crate::coordinator::WorkerCore): gossip receipt feeds
//! [`HealthChecker::observe`], a periodic cluster tick runs the checker
//! and (on the controller node — the lowest-id source) the autoscaler,
//! and every decision leaves the core as an `Action::Scale` for the
//! driver to apply. The DES and realtime drivers therefore run the
//! *identical* control loop; they differ only in who owns the clock and
//! how a fleet change is fanned out (one event vs. a shared scale bus).
//!
//! Applying a scale action reuses the churn machinery end to end: the
//! target gets a join/leave transition (a retiring worker drains its
//! queues and re-homes in-flight tasks — nothing is lost or duplicated),
//! and then the fleet **re-layers**: the driver rebuilds the routing
//! table over the active fleet
//! ([`RoutingTable::build_active`](crate::routing::RoutingTable::build_active))
//! and every core re-derives its next-hop row and
//! [`Role`](crate::routing::Role) from the
//! [`Placement`](crate::routing::Placement). In-flight tasks finish on
//! the layout they started on — they stay where they are queued and only
//! their *results* ride the new routes.
//!
//! ## Determinism contract
//!
//! * The only randomness is the health checker's per-peer deadline
//!   jitter, drawn from the dedicated registry stream
//!   [`streams::CLUSTER_HEALTH_BASE`](crate::util::rng::streams) ` + id`
//!   — one draw per (checker, peer), at first observation, in
//!   observation order. Enabling the control plane never perturbs the
//!   admission, offload, arrival, or link-jitter streams, and DES runs
//!   with it enabled are bit-for-bit reproducible across repeats.
//! * `cluster/` obeys the repo's clock-purity rule: no `Instant` /
//!   `SystemTime` — `now` always arrives as a value from the driver —
//!   and the panic-budget rule (no `unwrap`/`expect` in non-test code);
//!   both are enforced by `cargo xtask lint`.
//! * Default config (`enabled = false`) builds no runtime state, stamps
//!   no heartbeat, and keeps the seed's wire accounting bit-for-bit.
//!
//! ## Cooldown semantics
//!
//! Load-driven decisions (occupancy crossing the scale-up or scale-down
//! threshold) are rate-limited: after any such decision the autoscaler
//! refuses further load-driven action for `cooldown_s` simulated/wall
//! seconds, so an occupancy signal oscillating around a threshold cannot
//! thrash the fleet. Failure-driven retirement (a peer declared dead by
//! the health checker) bypasses the cooldown — dead is dead — but does
//! reset it, so a failover is not immediately followed by a load
//! decision made on a stale occupancy signal.

mod health;
mod scale;
mod score;

pub use health::HealthChecker;
pub use scale::{Autoscaler, ScaleDecision, ScaleDirection, ScaleReason};
pub use score::{retire_candidate, spawn_candidate, ScoreWeights};

use anyhow::{bail, Result};

/// `[cluster]` experiment-config section. Defaults keep the control
/// plane off (the seed fleet: everything active, churn purely scripted).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Master switch. Off: no heartbeats, no runtime state, seed wire
    /// accounting bit-for-bit.
    pub enabled: bool,
    /// Control-loop cadence, seconds (health check + autoscaler decide).
    pub check_interval_s: f64,
    /// Missed-beat tolerance: a peer is dead after this many expected
    /// gossip intervals pass without a fresh beat (before jitter).
    pub timeout_beats: f64,
    /// Fractional deadline jitter: each peer's death deadline is
    /// multiplied by `1 + jitter_frac * u`, `u ~ U[0,1)` from the
    /// registered health stream.
    pub jitter_frac: f64,
    /// Composite scorer weights (cpu / queue / link).
    pub weights: ScoreWeights,
    /// Mean queued tasks per active worker above which the controller
    /// spawns a parked worker.
    pub scale_up_occupancy: f64,
    /// Mean queued tasks per active worker below which the controller
    /// retires the worst-scored active worker.
    pub scale_down_occupancy: f64,
    /// Minimum seconds between load-driven scale decisions.
    pub cooldown_s: f64,
    /// Fleet floor (active nodes, sources included) — scale-down stops
    /// here.
    pub min_workers: usize,
    /// Fleet ceiling (active nodes) — scale-up stops here. Clamped to
    /// the topology size at run time.
    pub max_workers: usize,
    /// How many nodes start active (sources always do; the lowest-id
    /// non-sources fill the remainder). `None`: the whole topology.
    pub initial_workers: Option<usize>,
}

impl Default for ClusterConfig {
    fn default() -> ClusterConfig {
        ClusterConfig {
            enabled: false,
            check_interval_s: 0.5,
            timeout_beats: 3.0,
            jitter_frac: 0.2,
            weights: ScoreWeights::default(),
            scale_up_occupancy: 3.0,
            scale_down_occupancy: 0.5,
            cooldown_s: 1.0,
            min_workers: 1,
            max_workers: usize::MAX,
            initial_workers: None,
        }
    }
}

impl ClusterConfig {
    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        if !self.check_interval_s.is_finite() || self.check_interval_s <= 0.0 {
            bail!("cluster check_interval_s must be positive, got {}", self.check_interval_s);
        }
        if !self.timeout_beats.is_finite() || self.timeout_beats < 1.0 {
            bail!("cluster timeout_beats must be >= 1, got {}", self.timeout_beats);
        }
        if !self.jitter_frac.is_finite() || !(0.0..=1.0).contains(&self.jitter_frac) {
            bail!("cluster jitter_frac must be in [0, 1], got {}", self.jitter_frac);
        }
        self.weights.validate()?;
        if !self.scale_up_occupancy.is_finite()
            || !self.scale_down_occupancy.is_finite()
            || self.scale_down_occupancy < 0.0
            || self.scale_up_occupancy <= self.scale_down_occupancy
        {
            bail!(
                "cluster occupancy thresholds need 0 <= scale_down ({}) < scale_up ({})",
                self.scale_down_occupancy,
                self.scale_up_occupancy
            );
        }
        if !self.cooldown_s.is_finite() || self.cooldown_s < 0.0 {
            bail!("cluster cooldown_s must be >= 0, got {}", self.cooldown_s);
        }
        if self.min_workers == 0 || self.min_workers > self.max_workers {
            bail!(
                "cluster fleet bounds need 1 <= min_workers ({}) <= max_workers ({})",
                self.min_workers,
                self.max_workers
            );
        }
        if self.initial_workers == Some(0) {
            bail!("cluster initial_workers must be >= 1 when set");
        }
        Ok(())
    }
}

/// Nodes that start parked under `initial_workers`: sources always start
/// active (admission must be covered from t=0), then the lowest-id
/// non-sources fill the remaining budget; everyone else starts parked,
/// available for the autoscaler to wake. Shared by both drivers so the DES
/// and realtime fleets boot identically.
pub fn initial_parked(initial_workers: Option<usize>, sources: &[usize], n: usize) -> Vec<usize> {
    let Some(k) = initial_workers else {
        return Vec::new();
    };
    let mut budget = k.saturating_sub(sources.len());
    let mut parked = Vec::new();
    for node in 0..n {
        if sources.contains(&node) {
            continue;
        }
        if budget > 0 {
            budget -= 1;
        } else {
            parked.push(node);
        }
    }
    parked
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_parking_keeps_sources_and_fills_lowest_ids() {
        // 6 nodes, sources {0, 3}, budget 3: source slots consume 2, node 1
        // fills the last; 2, 4, 5 park.
        assert_eq!(initial_parked(Some(3), &[0, 3], 6), vec![2, 4, 5]);
        // Budget below the source count still keeps every source up.
        assert_eq!(initial_parked(Some(1), &[0, 3], 6), vec![1, 2, 4, 5]);
        // No budget set: nobody parks.
        assert_eq!(initial_parked(None, &[0], 4), Vec::<usize>::new());
        // Budget covers the fleet: nobody parks.
        assert_eq!(initial_parked(Some(9), &[0], 4), Vec::<usize>::new());
    }

    #[test]
    fn default_is_off_and_valid() {
        let c = ClusterConfig::default();
        assert!(!c.enabled);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn disabled_skips_field_validation() {
        let c = ClusterConfig { check_interval_s: -1.0, ..ClusterConfig::default() };
        assert!(c.validate().is_ok(), "off means off — fields are inert");
    }

    #[test]
    fn enabled_validation_rejects_bad_knobs() {
        let on = ClusterConfig { enabled: true, ..ClusterConfig::default() };
        assert!(on.validate().is_ok());
        for bad in [
            ClusterConfig { check_interval_s: 0.0, ..on.clone() },
            ClusterConfig { timeout_beats: 0.5, ..on.clone() },
            ClusterConfig { jitter_frac: 1.5, ..on.clone() },
            ClusterConfig { scale_up_occupancy: 0.4, ..on.clone() },
            ClusterConfig { scale_down_occupancy: -0.1, ..on.clone() },
            ClusterConfig { cooldown_s: f64::NAN, ..on.clone() },
            ClusterConfig { min_workers: 0, ..on.clone() },
            ClusterConfig { min_workers: 5, max_workers: 3, ..on.clone() },
            ClusterConfig { initial_workers: Some(0), ..on.clone() },
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
        }
    }
}
