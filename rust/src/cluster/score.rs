//! Composite node scoring: rank fleet members on cpu / queue / link.
//!
//! The scorer consumes what the control plane already has — the gossiped
//! [`NeighborSummary`] view of each peer — and produces a *cost* (higher
//! = worse): slow compute (Γ), deep input queue (I), and an expensive
//! link (the receiver-local transfer estimate `d_nm_s`) all raise it.
//! The autoscaler retires the highest-cost worker and, when spawning,
//! wakes the lowest-id parked node (parked nodes gossip nothing, so id
//! order is the only deterministic rank available for them).

use anyhow::{bail, Result};

use crate::policy::NeighborSummary;

/// Weights of the composite cost. Units are "queued-task equivalents":
/// the queue term counts tasks directly, the cpu term converts seconds
/// of per-task compute, and the link term converts seconds of transfer
/// delay — so the defaults value 20 ms of compute or 50 ms of link
/// delay like one queued task.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScoreWeights {
    /// Weight on the peer's per-task compute delay Γ (per second).
    pub cpu: f64,
    /// Weight on the peer's input-queue depth I (per task).
    pub queue: f64,
    /// Weight on the transfer-delay estimate to the peer (per second).
    pub link: f64,
}

impl Default for ScoreWeights {
    fn default() -> ScoreWeights {
        ScoreWeights { cpu: 50.0, queue: 1.0, link: 20.0 }
    }
}

impl ScoreWeights {
    pub fn validate(&self) -> Result<()> {
        for (name, w) in [("cpu", self.cpu), ("queue", self.queue), ("link", self.link)] {
            if !w.is_finite() || w < 0.0 {
                bail!("cluster score weight {name} must be finite and >= 0, got {w}");
            }
        }
        Ok(())
    }

    /// Composite cost of one peer as seen through its gossiped summary.
    pub fn cost(&self, s: &NeighborSummary) -> f64 {
        self.cpu * s.gamma_s + self.queue * s.input_len as f64 + self.link * s.d_nm_s
    }
}

/// The active worker the controller should retire: the highest-cost
/// eligible peer among those it holds views for. `eligible` gates out
/// sources, already-parked peers, and the controller itself. Cost ties
/// break toward the *highest* id, so the low-id backbone survives.
/// `None` when no eligible peer has gossiped a view.
pub fn retire_candidate(
    weights: &ScoreWeights,
    views: &[Option<NeighborSummary>],
    mut eligible: impl FnMut(usize) -> bool,
) -> Option<usize> {
    let mut best: Option<(f64, usize)> = None;
    for (m, view) in views.iter().enumerate() {
        let Some(s) = view else { continue };
        if !eligible(m) {
            continue;
        }
        let cost = weights.cost(s);
        let better = match best {
            None => true,
            Some((bc, bm)) => cost > bc || (cost == bc && m > bm),
        };
        if better {
            best = Some((cost, m));
        }
    }
    best.map(|(_, m)| m)
}

/// The parked node the controller should wake: the lowest eligible id.
pub fn spawn_candidate(n: usize, mut eligible: impl FnMut(usize) -> bool) -> Option<usize> {
    (0..n).find(|&m| eligible(m))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(input_len: usize, gamma_s: f64, d_nm_s: f64) -> Option<NeighborSummary> {
        let mut s = NeighborSummary::base(input_len, gamma_s, 0.9);
        s.d_nm_s = d_nm_s;
        Some(s)
    }

    #[test]
    fn cost_orders_on_each_axis() {
        let w = ScoreWeights::default();
        let lean = view(1, 0.002, 0.001).unwrap();
        assert!(w.cost(&view(5, 0.002, 0.001).unwrap()) > w.cost(&lean), "queue");
        assert!(w.cost(&view(1, 0.050, 0.001).unwrap()) > w.cost(&lean), "cpu");
        assert!(w.cost(&view(1, 0.002, 0.200).unwrap()) > w.cost(&lean), "link");
    }

    #[test]
    fn retire_picks_the_worst_eligible() {
        let w = ScoreWeights::default();
        let views = vec![
            None,                      // 0: controller — no self view
            view(2, 0.002, 0.001),     // 1: healthy
            view(9, 0.010, 0.020),     // 2: deep queue, slow, far
            view(1, 0.002, 0.001),     // 3: healthiest
            view(9, 0.010, 0.020),     // 4: ties with 2
        ];
        assert_eq!(retire_candidate(&w, &views, |_| true), Some(4), "ties go high-id");
        assert_eq!(retire_candidate(&w, &views, |m| m != 4), Some(2));
        assert_eq!(retire_candidate(&w, &views, |m| m == 0), None, "no view, no verdict");
    }

    #[test]
    fn spawn_picks_the_lowest_eligible_id() {
        assert_eq!(spawn_candidate(6, |m| m >= 3), Some(3));
        assert_eq!(spawn_candidate(6, |_| false), None);
    }

    #[test]
    fn weight_validation() {
        assert!(ScoreWeights::default().validate().is_ok());
        assert!(ScoreWeights { cpu: -1.0, ..Default::default() }.validate().is_err());
        assert!(ScoreWeights { link: f64::NAN, ..Default::default() }.validate().is_err());
    }
}
