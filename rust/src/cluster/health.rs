//! Missed-beat health checking over the gossip heartbeat.
//!
//! Every summary minted with the control plane on carries a monotone
//! `beat` sequence number. The checker tracks, per peer it has *heard
//! from*, the freshest beat and when it arrived; a peer is declared dead
//! only after `timeout_beats` expected gossip intervals pass with no
//! strictly newer beat. Peers never heard from are never judged — the
//! gossip horizon (who a node exchanges summaries with) bounds who it
//! may declare dead.
//!
//! Each peer's deadline is stretched by a one-shot jitter factor
//! `1 + jitter_frac · u`, `u ~ U[0,1)` drawn from the registered
//! [`streams::CLUSTER_HEALTH_BASE`] stream at first observation — one
//! draw per (checker, peer), in observation order, so DES replays are
//! bit-for-bit and simultaneous expiries desynchronize instead of
//! stampeding the autoscaler.

use std::collections::BTreeMap;

use crate::util::rng::{streams, Pcg64};

#[derive(Debug, Clone, Copy)]
struct PeerBeat {
    /// Freshest beat sequence number seen.
    beat: u64,
    /// When it arrived (driver time, seconds).
    seen_s: f64,
    /// One-shot deadline stretch, `>= 1`.
    deadline_mult: f64,
    /// Already declared dead (suppresses repeat declarations until a
    /// fresh beat revives the peer).
    dead: bool,
}

/// Per-node missed-beat detector (see the module docs for the contract).
#[derive(Debug, Clone)]
pub struct HealthChecker {
    /// Expected beat spacing (the run's gossip interval), seconds.
    interval_s: f64,
    /// Missed-beat tolerance in expected intervals.
    timeout_beats: f64,
    /// Fractional deadline jitter.
    jitter_frac: f64,
    rng: Pcg64,
    peers: BTreeMap<usize, PeerBeat>,
}

impl HealthChecker {
    /// `id` is the hosting node — it selects the checker's dedicated
    /// stream in the RNG registry.
    pub fn new(
        seed: u64,
        id: usize,
        interval_s: f64,
        timeout_beats: f64,
        jitter_frac: f64,
    ) -> HealthChecker {
        HealthChecker {
            interval_s,
            timeout_beats,
            jitter_frac,
            rng: Pcg64::new(seed, streams::CLUSTER_HEALTH_BASE + id as u64),
            peers: BTreeMap::new(),
        }
    }

    /// Feed one gossip receipt. `beat = None` (control plane off at the
    /// sender, or a pre-upgrade summary) is ignored. Only a strictly
    /// newer beat refreshes liveness — a stale duplicate re-delivered by
    /// piggybacking cannot keep a dead sender alive.
    pub fn observe(&mut self, now: f64, peer: usize, beat: Option<u64>) {
        let Some(beat) = beat else { return };
        match self.peers.get_mut(&peer) {
            Some(p) => {
                if beat > p.beat {
                    p.beat = beat;
                    p.seen_s = now;
                    p.dead = false;
                }
            }
            None => {
                let deadline_mult = 1.0 + self.jitter_frac * self.rng.f64();
                self.peers.insert(peer, PeerBeat { beat, seen_s: now, deadline_mult, dead: false });
            }
        }
    }

    /// Stop tracking a peer (it was retired on purpose — its silence is
    /// not evidence).
    pub fn forget(&mut self, peer: usize) {
        self.peers.remove(&peer);
    }

    /// Sweep all tracked peers; returns the peers *newly* declared dead
    /// this check, in ascending id order.
    pub fn check(&mut self, now: f64) -> Vec<usize> {
        let mut newly_dead = Vec::new();
        for (&peer, p) in self.peers.iter_mut() {
            if p.dead {
                continue;
            }
            let deadline = self.interval_s * self.timeout_beats * p.deadline_mult;
            if now - p.seen_s > deadline {
                p.dead = true;
                newly_dead.push(peer);
            }
        }
        newly_dead
    }

    /// Whether `peer` is currently considered dead.
    pub fn is_dead(&self, peer: usize) -> bool {
        self.peers.get(&peer).is_some_and(|p| p.dead)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn checker() -> HealthChecker {
        // interval 0.1 s, 3 missed beats, up to +20% jitter.
        HealthChecker::new(7, 0, 0.1, 3.0, 0.2)
    }

    #[test]
    fn jittery_but_alive_is_never_declared_dead() {
        let mut hc = checker();
        // Beats arrive with heavy arrival jitter — anywhere from 0.02 s
        // to 0.19 s apart (mean 0.1 s) — but each one is fresh. The
        // deadline is >= 0.3 s, so a live-but-jittery peer must survive
        // every sweep.
        let gaps = [0.10, 0.19, 0.02, 0.15, 0.08, 0.18, 0.05, 0.19, 0.11, 0.16];
        let mut now = 0.0;
        hc.observe(now, 3, Some(0));
        for (i, gap) in gaps.iter().enumerate() {
            now += gap;
            assert!(hc.check(now).is_empty(), "live peer declared dead at beat {i}");
            hc.observe(now, 3, Some(i as u64 + 1));
            assert!(!hc.is_dead(3));
        }
    }

    #[test]
    fn silent_peer_is_declared_dead_once() {
        let mut hc = checker();
        hc.observe(0.0, 3, Some(0));
        hc.observe(0.0, 5, Some(0));
        hc.observe(0.05, 5, Some(1)); // peer 5 keeps beating
        assert!(hc.check(0.2).is_empty(), "before the deadline");
        // Keep 5 alive past 3's deadline (jitter caps it at 0.36 s).
        hc.observe(0.3, 5, Some(2));
        let dead = hc.check(0.4);
        assert_eq!(dead, vec![3], "only the silent peer dies");
        assert!(hc.is_dead(3));
        assert!(!hc.is_dead(5));
        assert!(hc.check(0.9).contains(&5), "then 5 goes silent too");
        assert!(hc.check(5.0).is_empty(), "declarations fire once");
    }

    #[test]
    fn stale_duplicate_beats_do_not_revive() {
        let mut hc = checker();
        hc.observe(0.0, 2, Some(7));
        hc.observe(0.2, 2, Some(7)); // piggybacked duplicate, same beat
        hc.observe(0.35, 2, Some(7));
        assert_eq!(hc.check(0.4), vec![2], "stale beats never refreshed liveness");
        // A strictly fresh beat revives.
        hc.observe(0.45, 2, Some(8));
        assert!(!hc.is_dead(2));
        assert!(hc.check(0.5).is_empty());
    }

    #[test]
    fn unheard_and_beatless_peers_are_never_judged() {
        let mut hc = checker();
        hc.observe(0.0, 4, None); // control plane off at the sender
        assert!(hc.check(100.0).is_empty());
        assert!(!hc.is_dead(4));
        assert!(!hc.is_dead(9), "never observed, never judged");
    }

    #[test]
    fn forget_drops_tracking() {
        let mut hc = checker();
        hc.observe(0.0, 3, Some(0));
        hc.forget(3);
        assert!(hc.check(10.0).is_empty(), "retired on purpose — silence is not evidence");
    }

    #[test]
    fn deterministic_across_replays() {
        let run = || {
            let mut hc = checker();
            hc.observe(0.0, 1, Some(0));
            hc.observe(0.0, 2, Some(0));
            hc.observe(0.31, 1, Some(1));
            let mut log = Vec::new();
            for i in 1..=20 {
                log.extend(hc.check(0.05 * i as f64));
            }
            log
        };
        assert_eq!(run(), run(), "same seed, same declarations");
    }
}
