//! Occupancy-driven autoscaling with thrash-preventing cooldowns.
//!
//! The autoscaler owns exactly one decision: given the controller's
//! aggregate occupancy signal (mean queued tasks per active worker,
//! over the gossip horizon), should the fleet grow, shrink, or hold?
//! Target selection — *which* node to wake or retire — belongs to the
//! scorer ([`super::score`]); applying the decision belongs to the
//! drivers. The state here is one timestamp: the last decision time,
//! enforcing the cooldown documented in the module docs of
//! [`crate::cluster`].

/// Grow or shrink — the autoscaler's whole vocabulary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDirection {
    Up,
    Down,
}

/// Why a fleet change was ordered (telemetry and the run report keep
/// the distinction: load decisions are tunable, failures are not).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleReason {
    /// Occupancy crossed a threshold.
    Load,
    /// The health checker declared the worker dead.
    Failure,
}

impl ScaleReason {
    pub fn label(self) -> &'static str {
        match self {
            ScaleReason::Load => "load",
            ScaleReason::Failure => "failure",
        }
    }
}

/// A concrete fleet change: `join = true` wakes a parked worker,
/// `join = false` retires an active one. Emitted by the controller core
/// as an `Action::Scale`; both drivers apply it through the same churn +
/// re-layer path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScaleDecision {
    pub worker: usize,
    pub join: bool,
    pub reason: ScaleReason,
}

/// Threshold-and-cooldown scaling policy (see module docs).
#[derive(Debug, Clone)]
pub struct Autoscaler {
    up_occupancy: f64,
    down_occupancy: f64,
    cooldown_s: f64,
    min_workers: usize,
    max_workers: usize,
    last_action_s: f64,
}

impl Autoscaler {
    pub fn new(cfg: &super::ClusterConfig) -> Autoscaler {
        Autoscaler {
            up_occupancy: cfg.scale_up_occupancy,
            down_occupancy: cfg.scale_down_occupancy,
            cooldown_s: cfg.cooldown_s,
            min_workers: cfg.min_workers,
            max_workers: cfg.max_workers,
            last_action_s: f64::NEG_INFINITY,
        }
    }

    /// One load-driven decision. `active` counts every active node
    /// (sources included); `can_grow` / `can_shrink` tell the policy
    /// whether a concrete target exists (a parked node to wake, an
    /// eligible worker to retire). Returns `None` inside the cooldown
    /// window, inside the occupancy deadband, or at a fleet bound.
    pub fn decide(
        &mut self,
        now: f64,
        occupancy: f64,
        active: usize,
        can_grow: bool,
        can_shrink: bool,
    ) -> Option<ScaleDirection> {
        if now - self.last_action_s < self.cooldown_s {
            return None;
        }
        let dir = if occupancy >= self.up_occupancy && active < self.max_workers && can_grow {
            ScaleDirection::Up
        } else if occupancy <= self.down_occupancy && active > self.min_workers && can_shrink {
            ScaleDirection::Down
        } else {
            return None;
        };
        self.last_action_s = now;
        Some(dir)
    }

    /// A failure-driven retirement happened outside the load policy.
    /// Dead is dead — no cooldown gates it — but the cooldown restarts
    /// so the next *load* decision waits for a post-failover signal.
    pub fn note_failure(&mut self, now: f64) {
        self.last_action_s = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    fn scaler() -> Autoscaler {
        // up at 3.0, down at 0.5, cooldown 1 s, fleet in [1, 4].
        Autoscaler::new(&ClusterConfig {
            enabled: true,
            max_workers: 4,
            ..ClusterConfig::default()
        })
    }

    #[test]
    fn thresholds_and_deadband() {
        let mut s = scaler();
        assert_eq!(s.decide(0.0, 1.0, 2, true, true), None, "deadband holds");
        assert_eq!(s.decide(0.0, 3.5, 2, true, true), Some(ScaleDirection::Up));
        let mut s = scaler();
        assert_eq!(s.decide(0.0, 0.2, 2, true, true), Some(ScaleDirection::Down));
    }

    #[test]
    fn cooldown_blocks_thrash() {
        let mut s = scaler();
        assert_eq!(s.decide(0.0, 5.0, 2, true, true), Some(ScaleDirection::Up));
        assert_eq!(s.decide(0.5, 0.1, 3, true, true), None, "inside cooldown");
        assert_eq!(s.decide(1.0, 0.1, 3, true, true), Some(ScaleDirection::Down));
    }

    #[test]
    fn fleet_bounds_and_target_availability() {
        let mut s = scaler();
        assert_eq!(s.decide(0.0, 9.0, 4, true, true), None, "at max_workers");
        assert_eq!(s.decide(0.0, 9.0, 3, false, true), None, "nothing parked to wake");
        assert_eq!(s.decide(0.0, 0.0, 1, true, true), None, "at min_workers");
        assert_eq!(s.decide(0.0, 0.0, 2, true, false), None, "no eligible retiree");
        assert_eq!(s.decide(0.0, 0.0, 2, true, true), Some(ScaleDirection::Down));
    }

    #[test]
    fn failure_resets_the_cooldown() {
        let mut s = scaler();
        s.note_failure(10.0);
        assert_eq!(s.decide(10.5, 9.0, 2, true, true), None, "failover just happened");
        assert_eq!(s.decide(11.1, 9.0, 2, true, true), Some(ScaleDirection::Up));
    }
}
