//! Artifact manifest: the contract between the Python AOT pipeline and the
//! Rust runtime. `python/compile/aot.py` writes `artifacts/manifest.json`;
//! this module parses and validates it.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Per-stage record: task τ_k of the partitioned model.
#[derive(Debug, Clone)]
pub struct StageInfo {
    pub k: usize,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub probs_dim: usize,
    /// HLO text path, relative to the artifacts dir.
    pub hlo: String,
    /// Median compute cost of this stage on the build machine (ms);
    /// simnet scales it per worker to recreate device heterogeneity.
    pub cost_ms: f64,
    pub in_bytes: usize,
    pub out_bytes: usize,
}

/// Autoencoder at the ResNet stage-1 boundary (paper §V).
#[derive(Debug, Clone)]
pub struct AeInfo {
    pub enc_hlo: String,
    pub dec_hlo: String,
    pub code_shape: Vec<usize>,
    pub code_bytes: usize,
    pub raw_bytes: usize,
    pub compression: f64,
    pub acc_drop: Vec<f64>,
    pub enc_cost_ms: f64,
    pub dec_cost_ms: f64,
    pub exits_bin_ae: String,
}

#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub num_stages: usize,
    pub stages: Vec<StageInfo>,
    pub exits_bin: String,
    /// Held-out accuracy if *every* sample exited at point k (Fig. 2 data).
    pub exit_accuracy: Vec<f64>,
    pub mean_confidence: Vec<f64>,
    pub ae: Option<AeInfo>,
}

#[derive(Debug, Clone)]
pub struct DatasetInfo {
    pub file: String,
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub num_classes: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub dataset: DatasetInfo,
    pub models: BTreeMap<String, ModelInfo>,
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .context("expected shape array")?
        .iter()
        .map(|v| v.as_usize().context("bad shape dim"))
        .collect()
}

fn f64s_of(j: &Json) -> Result<Vec<f64>> {
    j.as_arr()
        .context("expected number array")?
        .iter()
        .map(|v| v.as_f64().context("bad number"))
        .collect()
}

impl Manifest {
    /// Parse `<dir>/manifest.json` and validate internal consistency.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;

        let d = root.get("dataset");
        let dataset = DatasetInfo {
            file: d.get("file").as_str().context("dataset.file")?.to_string(),
            n: d.get("n").as_usize().context("dataset.n")?,
            h: d.get("h").as_usize().context("dataset.h")?,
            w: d.get("w").as_usize().context("dataset.w")?,
            c: d.get("c").as_usize().context("dataset.c")?,
            num_classes: d.get("num_classes").as_usize().context("dataset.num_classes")?,
        };

        let mut models = BTreeMap::new();
        let mobj = root.get("models").as_obj().context("models")?;
        for (name, m) in mobj {
            models.insert(name.clone(), parse_model(name, m)?);
        }
        let manifest = Manifest { dir, dataset, models };
        manifest.validate()?;
        Ok(manifest)
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .with_context(|| format!("model {name:?} not in manifest ({:?})",
                                     self.models.keys().collect::<Vec<_>>()))
    }

    /// Absolute path of an artifact file referenced by the manifest.
    pub fn path(&self, rel: &str) -> PathBuf {
        self.dir.join(rel)
    }

    fn validate(&self) -> Result<()> {
        for (name, m) in &self.models {
            if m.stages.len() != m.num_stages {
                bail!("{name}: {} stages listed, num_stages={}", m.stages.len(), m.num_stages);
            }
            for (i, s) in m.stages.iter().enumerate() {
                if s.k != i + 1 {
                    bail!("{name}: stage {} out of order (k={})", i + 1, s.k);
                }
                if i + 1 < m.stages.len() && s.out_shape != m.stages[i + 1].in_shape {
                    bail!("{name}: stage {} out_shape {:?} != stage {} in_shape {:?}",
                          s.k, s.out_shape, s.k + 1, m.stages[i + 1].in_shape);
                }
                if s.cost_ms <= 0.0 {
                    bail!("{name}: stage {} non-positive cost", s.k);
                }
            }
            if m.exit_accuracy.len() != m.num_stages {
                bail!("{name}: exit_accuracy length mismatch");
            }
        }
        Ok(())
    }
}

fn parse_model(name: &str, m: &Json) -> Result<ModelInfo> {
    let mut stages = Vec::new();
    for s in m.get("stages").as_arr().context("stages")? {
        stages.push(StageInfo {
            k: s.get("k").as_usize().context("stage.k")?,
            in_shape: shape_of(s.get("in_shape"))?,
            out_shape: shape_of(s.get("out_shape"))?,
            probs_dim: s.get("probs_dim").as_usize().context("probs_dim")?,
            hlo: s.get("hlo").as_str().context("hlo")?.to_string(),
            cost_ms: s.get("cost_ms").as_f64().context("cost_ms")?,
            in_bytes: s.get("in_bytes").as_usize().context("in_bytes")?,
            out_bytes: s.get("out_bytes").as_usize().context("out_bytes")?,
        });
    }
    let ae_json = m.get("ae");
    let ae = if ae_json.is_null() {
        None
    } else {
        Some(AeInfo {
            enc_hlo: ae_json.get("enc_hlo").as_str().context("ae.enc_hlo")?.to_string(),
            dec_hlo: ae_json.get("dec_hlo").as_str().context("ae.dec_hlo")?.to_string(),
            code_shape: shape_of(ae_json.get("code_shape"))?,
            code_bytes: ae_json.get("code_bytes").as_usize().context("ae.code_bytes")?,
            raw_bytes: ae_json.get("raw_bytes").as_usize().context("ae.raw_bytes")?,
            compression: ae_json.get("compression").as_f64().unwrap_or(0.0),
            acc_drop: f64s_of(ae_json.get("acc_drop"))?,
            enc_cost_ms: ae_json.get("enc_cost_ms").as_f64().context("ae.enc_cost_ms")?,
            dec_cost_ms: ae_json.get("dec_cost_ms").as_f64().context("ae.dec_cost_ms")?,
            exits_bin_ae: ae_json.get("exits_bin_ae").as_str().context("ae.exits_bin_ae")?.to_string(),
        })
    };
    Ok(ModelInfo {
        name: name.to_string(),
        num_stages: m.get("num_stages").as_usize().context("num_stages")?,
        stages,
        exits_bin: m.get("exits_bin").as_str().context("exits_bin")?.to_string(),
        exit_accuracy: f64s_of(m.get("exit_accuracy"))?,
        mean_confidence: f64s_of(m.get("mean_confidence"))?,
        ae,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest_json() -> String {
        r#"{
          "version": 1,
          "dataset": {"file":"dataset.bin","n":16,"h":32,"w":32,"c":3,"num_classes":10},
          "models": {
            "tiny": {
              "num_stages": 2,
              "stages": [
                {"k":1,"in_shape":[32,32,3],"out_shape":[16,16,8],"probs_dim":10,
                 "hlo":"tiny/stage1.hlo.txt","cost_ms":1.5,"in_bytes":12288,"out_bytes":8192},
                {"k":2,"in_shape":[16,16,8],"out_shape":[8,8,16],"probs_dim":10,
                 "hlo":"tiny/stage2.hlo.txt","cost_ms":2.0,"in_bytes":8192,"out_bytes":4096}
              ],
              "exits_bin": "exits_tiny.bin",
              "exit_accuracy": [0.6, 0.8],
              "mean_confidence": [0.7, 0.9],
              "ae": null
            }
          }
        }"#
        .to_string()
    }

    fn write_manifest(body: &str) -> tempdir::TempDir {
        let td = tempdir::TempDir::new();
        std::fs::write(td.path().join("manifest.json"), body).unwrap();
        td
    }

    // Minimal tempdir helper (no tempfile crate offline).
    mod tempdir {
        use std::path::{Path, PathBuf};
        use std::sync::atomic::{AtomicU64, Ordering};
        static CTR: AtomicU64 = AtomicU64::new(0);
        pub struct TempDir(PathBuf);
        impl TempDir {
            pub fn new() -> TempDir {
                let id = CTR.fetch_add(1, Ordering::Relaxed);
                let p = std::env::temp_dir()
                    .join(format!("mdi-test-{}-{}", std::process::id(), id));
                std::fs::create_dir_all(&p).unwrap();
                TempDir(p)
            }
            pub fn path(&self) -> &Path {
                &self.0
            }
        }
        impl Drop for TempDir {
            fn drop(&mut self) {
                let _ = std::fs::remove_dir_all(&self.0);
            }
        }
    }

    #[test]
    fn loads_valid_manifest() {
        let td = write_manifest(&sample_manifest_json());
        let m = Manifest::load(td.path()).unwrap();
        assert_eq!(m.dataset.n, 16);
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.num_stages, 2);
        assert_eq!(tiny.stages[0].out_shape, vec![16, 16, 8]);
        assert!(tiny.ae.is_none());
        assert!(m.path(&tiny.stages[0].hlo).ends_with("tiny/stage1.hlo.txt"));
    }

    #[test]
    fn rejects_shape_chain_mismatch() {
        let body = sample_manifest_json().replace("[16,16,8]", "[16,16,9]");
        // breaks stage1.out == stage2.in (replaces both occurrences, so
        // tweak only the in_shape of stage 2 back)
        let body = body.replacen("\"in_shape\":[16,16,9]", "\"in_shape\":[16,16,8]", 1);
        let td = write_manifest(&body);
        // one of the two orders breaks the chain either way
        assert!(Manifest::load(td.path()).is_err());
    }

    #[test]
    fn rejects_missing_model() {
        let td = write_manifest(&sample_manifest_json());
        let m = Manifest::load(td.path()).unwrap();
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn missing_file_mentions_make_artifacts() {
        let err = Manifest::load("/nonexistent-dir-xyz").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
