//! Deficit round robin: weighted-fair service across traffic classes.
//!
//! [`super::StrictPriority`] keeps class 0 fast by starving everyone else —
//! under sustained class-0 overload, bulk classes never run (the ROADMAP
//! follow-on this discipline closes). DRR instead gives each class a
//! *quantum* of service credit per rotation: a class with quantum 2 is
//! served twice as often as a class with quantum 1, every class with a
//! positive quantum is served eventually, and within a class service is
//! FIFO. Quanta come from [`super::SchedConfig::class_quantum`] (weights,
//! not priorities — they need not sum to anything).
//!
//! The per-class deficit counters are the scheduler's live state; the
//! per-class *served* counters ([`QueueDiscipline::served_per_class`])
//! surface the realized service split in the run report, so a
//! mis-weighted run is visible instead of inferred.

use std::collections::VecDeque;

use super::discipline::QueueDiscipline;
use crate::coordinator::task::Task;

/// Deficit-round-robin across N class lanes, FIFO within a lane. Tasks
/// with `class >= num_classes` land in the last lane (same clamp rule as
/// [`super::StrictPriority`]).
#[derive(Debug)]
pub struct Drr {
    lanes: Vec<VecDeque<(u64, Task)>>,
    /// Service credit added to a lane each time the rotation passes it.
    quantum: Vec<f64>,
    /// Accumulated unspent credit per lane (one pop costs 1.0).
    deficit: Vec<f64>,
    /// Lane the rotation currently serves.
    cursor: usize,
    seq: u64,
    len: usize,
    peak: usize,
    total_enqueued: u64,
    /// Tasks actually popped per lane (report surface).
    served: Vec<u64>,
}

impl Drr {
    /// One lane per class; `quantum` must have one positive entry per
    /// class (validated by `SchedConfig::validate`).
    pub fn new(num_classes: u8, quantum: Vec<f64>) -> Drr {
        let n = num_classes.max(1) as usize;
        let mut quantum = quantum;
        quantum.resize(n, quantum.last().copied().unwrap_or(1.0));
        Drr {
            lanes: (0..n).map(|_| VecDeque::new()).collect(),
            quantum,
            deficit: vec![0.0; n],
            cursor: 0,
            seq: 0,
            len: 0,
            peak: 0,
            total_enqueued: 0,
            served: vec![0; n],
        }
    }

    fn lane_of(&self, class: u8) -> usize {
        (class as usize).min(self.lanes.len() - 1)
    }

    fn advance(&mut self) {
        self.cursor = (self.cursor + 1) % self.lanes.len();
    }
}

impl QueueDiscipline for Drr {
    fn push(&mut self, t: Task) {
        self.seq += 1;
        let lane = self.lane_of(t.class);
        self.lanes[lane].push_back((self.seq, t));
        self.len += 1;
        self.peak = self.peak.max(self.len);
        self.total_enqueued += 1;
    }

    fn pop_next(&mut self, _now: f64) -> Option<Task> {
        if self.len == 0 {
            return None;
        }
        // Rotate, feeding each occupied lane its quantum, until one can
        // afford a pop. Terminates: some lane is occupied and its deficit
        // grows by a positive quantum every rotation.
        loop {
            let lane = self.cursor;
            if self.lanes[lane].is_empty() {
                // An idle lane keeps no credit (classic DRR: deficit
                // resets when the lane empties, so idle classes cannot
                // hoard service for later bursts).
                self.deficit[lane] = 0.0;
                self.advance();
                continue;
            }
            if self.deficit[lane] >= 1.0 {
                self.deficit[lane] -= 1.0;
                let (_, t) = self.lanes[lane].pop_front().expect("non-empty lane");
                self.len -= 1;
                self.served[lane] += 1;
                if self.lanes[lane].is_empty() {
                    self.deficit[lane] = 0.0;
                    self.advance();
                }
                return Some(t);
            }
            self.deficit[lane] += self.quantum[lane];
            self.advance();
        }
    }

    fn peek(&self) -> Option<&Task> {
        // The task the rotation would serve next: walk from the cursor,
        // simulating (without mutating) the deficit top-ups.
        if self.len == 0 {
            return None;
        }
        let n = self.lanes.len();
        let mut deficit = self.deficit.clone();
        let mut at = self.cursor;
        loop {
            if let Some((_, t)) = self.lanes[at].front() {
                if deficit[at] >= 1.0 {
                    return Some(t);
                }
                deficit[at] += self.quantum[at];
            }
            at = (at + 1) % n;
        }
    }

    fn len(&self) -> usize {
        self.len
    }

    fn peak(&self) -> usize {
        self.peak
    }

    fn total_enqueued(&self) -> u64 {
        self.total_enqueued
    }

    fn class_len(&self, class: u8) -> usize {
        if (class as usize) < self.lanes.len() {
            self.lanes[class as usize].iter().filter(|(_, t)| t.class == class).count()
        } else {
            0
        }
    }

    fn served_per_class(&self) -> &[u64] {
        &self.served
    }

    fn earliest_deadline(&self) -> Option<f64> {
        self.lanes
            .iter()
            .flat_map(|l| l.iter().map(|(_, t)| t.deadline))
            .min_by(f64::total_cmp)
    }

    fn coalescible_run(&self, max: usize, same_class: bool) -> usize {
        // Service order depends on the rotating deficits; estimate from a
        // bounded probe (uniform sample -> full run, else the safe lower
        // bound). The cap keeps deep backlogs off an O(n)-per-offload
        // scan; an optimistic hint only prices the envelope — the drain
        // re-checks every pop.
        const PROBE: usize = 64;
        let Some(head) = self.peek() else { return 0 };
        let (stage, class) = (head.stage, head.class);
        let uniform = self
            .lanes
            .iter()
            .flat_map(|l| l.iter())
            .take(PROBE)
            .all(|(_, t)| t.stage == stage && (!same_class || t.class == class));
        if uniform {
            self.len.min(max)
        } else {
            1.min(max)
        }
    }

    fn drain_all(&mut self) -> Vec<Task> {
        let mut all: Vec<(u64, Task)> =
            self.lanes.iter_mut().flat_map(|l| l.drain(..)).collect();
        all.sort_by_key(|(seq, _)| *seq);
        self.len = 0;
        self.deficit.iter_mut().for_each(|d| *d = 0.0);
        self.cursor = 0;
        all.into_iter().map(|(_, t)| t).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(id: u64, class: u8) -> Task {
        Task { class, ..Task::initial(id, id as usize, None, 0.0) }
    }

    fn service_order(q: &mut Drr, n: usize) -> Vec<u8> {
        (0..n).filter_map(|_| q.pop_next(0.0)).map(|t| t.class).collect()
    }

    #[test]
    fn equal_quanta_alternate_between_backlogged_classes() {
        let mut q = Drr::new(2, vec![1.0, 1.0]);
        for i in 0..4 {
            q.push(task(i, 0));
            q.push(task(10 + i, 1));
        }
        let order = service_order(&mut q, 8);
        assert_eq!(order, vec![0, 1, 0, 1, 0, 1, 0, 1]);
    }

    #[test]
    fn weighted_quanta_split_service_proportionally() {
        let mut q = Drr::new(2, vec![2.0, 1.0]);
        for i in 0..20 {
            q.push(task(i, 0));
            q.push(task(100 + i, 1));
        }
        let order = service_order(&mut q, 12);
        let c0 = order.iter().filter(|&&c| c == 0).count();
        let c1 = order.iter().filter(|&&c| c == 1).count();
        assert_eq!((c0, c1), (8, 4), "2:1 quanta give a 2:1 service split: {order:?}");
        assert_eq!(q.served_per_class(), &[8, 4][..]);
    }

    #[test]
    fn no_class_starves_unlike_strict_priority() {
        // A flood of class-0 work with one class-1 task queued behind it:
        // strict priority would hold the class-1 task until the flood
        // drains; DRR serves it within one rotation.
        let mut q = Drr::new(2, vec![1.0, 1.0]);
        for i in 0..50 {
            q.push(task(i, 0));
        }
        q.push(task(99, 1));
        let order = service_order(&mut q, 3);
        assert!(
            order.contains(&1),
            "class 1 must be served within the first rotation: {order:?}"
        );
    }

    #[test]
    fn fifo_within_a_class_and_empty_lanes_skip() {
        let mut q = Drr::new(3, vec![1.0, 1.0, 1.0]);
        q.push(task(1, 2));
        q.push(task(2, 2));
        q.push(task(3, 2));
        // Only lane 2 is occupied: service is plain FIFO.
        let ids: Vec<u64> =
            (0..3).filter_map(|_| q.pop_next(0.0)).map(|t| t.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
        assert!(q.pop_next(0.0).is_none());
    }

    #[test]
    fn idle_lanes_do_not_hoard_credit() {
        let mut q = Drr::new(2, vec![1.0, 1.0]);
        // Lane 1 idles through many lane-0 pops...
        for i in 0..10 {
            q.push(task(i, 0));
        }
        for _ in 0..10 {
            q.pop_next(0.0);
        }
        // ...then both backlogs arrive: service must still alternate, not
        // burst lane 1 on banked credit.
        for i in 0..4 {
            q.push(task(20 + i, 0));
            q.push(task(30 + i, 1));
        }
        let order = service_order(&mut q, 4);
        let c1 = order.iter().filter(|&&c| c == 1).count();
        assert!(c1 <= 2, "no credit hoarding: {order:?}");
    }

    #[test]
    fn peek_matches_pop_without_mutating() {
        let mut q = Drr::new(2, vec![1.0, 1.0]);
        q.push(task(1, 1));
        q.push(task(2, 0));
        for _ in 0..4 {
            let peeked = q.peek().map(|t| t.id);
            let popped = q.pop_next(0.0).map(|t| t.id);
            assert_eq!(peeked, popped);
            if popped.is_none() {
                break;
            }
        }
    }

    #[test]
    fn clamps_out_of_range_classes_into_last_lane() {
        let mut q = Drr::new(2, vec![1.0, 1.0]);
        q.push(task(1, 9));
        assert_eq!(q.class_len(9), 0, "clamped classes report 0 beyond lanes");
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop_next(0.0).unwrap().id, 1);
    }

    #[test]
    fn accounting_and_drain_preserve_invariants() {
        let mut q = Drr::new(2, vec![1.0, 1.0]);
        q.push(task(1, 1));
        q.push(task(2, 0));
        q.push(task(3, 1));
        q.pop_next(0.0);
        assert_eq!(q.len(), 2);
        assert_eq!(q.peak(), 3);
        assert_eq!(q.total_enqueued(), 3);
        let ids: Vec<u64> = q.drain_all().iter().map(|t| t.id).collect();
        // Arrival order among the remaining tasks, regardless of lanes.
        assert!(ids == vec![1, 3] || ids == vec![2, 3], "drain keeps arrival order: {ids:?}");
        assert_eq!(q.len(), 0);
        assert_eq!(q.peak(), 3, "drain must not reset peak");
        assert_eq!(q.total_enqueued(), 3);
    }

    #[test]
    fn earliest_deadline_scans_all_lanes() {
        let mut q = Drr::new(2, vec![1.0, 1.0]);
        q.push(Task { deadline: 5.0, ..task(1, 0) });
        q.push(Task { deadline: 2.0, ..task(2, 1) });
        assert_eq!(q.earliest_deadline(), Some(2.0));
    }
}
