//! Pluggable scheduling: queue disciplines, traffic classes, and batching.
//!
//! The paper's Algorithms 1–4 consume queue *lengths* only, so the FIFO
//! order of the seed's `TaskQueue` was an implementation accident, not a
//! design requirement. This module turns the per-worker queues into a
//! policy surface consumed by [`crate::coordinator::WorkerCore`]:
//!
//! * [`QueueDiscipline`] — the trait the core's I_n/O_n queues implement
//!   (push / pop-next / peek / occupancy accounting / per-class lengths /
//!   arrival-order drain for churn re-homing).
//! * [`Fifo`] — the paper's baseline, bit-for-bit the seed behaviour
//!   (backed by the original [`crate::coordinator::queues::TaskQueue`]).
//! * [`StrictPriority`] — N traffic classes, lower class number served
//!   first, FIFO within a class. Models the class-aware queueing of
//!   *Priority-Aware Model-Distributed Inference at Edge Networks*
//!   (arXiv 2412.12371, PAPERS.md): under overload, deadline-critical
//!   traffic keeps a short queue while bulk traffic absorbs the backlog.
//! * [`Edf`] — earliest-deadline-first. Deadlines are stamped at admission
//!   from a per-class latency budget ([`SchedConfig::class_deadline_s`]);
//!   with [`DisciplineKind::Edf`]`::drop_late` the discipline ages out
//!   tasks whose deadline already passed instead of wasting compute on
//!   them (counted per class in the run report).
//! * [`Drr`] — deficit round robin: weighted-fair service across classes
//!   (quantum per class from [`SchedConfig::class_quantum`]), closing the
//!   starvation hole `StrictPriority` leaves open — bulk classes keep a
//!   bounded share of service under class-0 overload, and the realized
//!   split is reported via `served_per_class`.
//! * [`BatchPolicy`] — lets the core's `poll_next` form a *same-stage*
//!   batch so one `StartCompute` carries several tasks and the engine runs
//!   one batched forward per stage. This is the DEFER insight (arXiv
//!   2201.06769, PAPERS.md): distributed-edge throughput comes from
//!   amortizing the fixed per-stage dispatch cost over a batch.
//!
//! Every discipline preserves three invariants the coordinator relies on:
//! `len()` is the live occupancy signal for Algs 1–4, `peak()` /
//! `total_enqueued()` are monotone accounting that survives churn drains,
//! and `drain_all()` returns tasks in *arrival order* so re-homed work
//! replays at the source in the order it was admitted.

mod batch;
mod discipline;
mod drr;
mod priority;

pub use batch::BatchPolicy;
pub use discipline::{Fifo, QueueDiscipline};
pub use drr::Drr;
pub use priority::{Edf, StrictPriority};

/// Whether (and how) an offloading worker drains a *run* of queued tasks
/// into one [`crate::net::Envelope`] instead of sending them one at a time
/// — the wire analogue of [`BatchPolicy`]'s engine batching. The receiver
/// merges the batch through its own discipline in admission order, so
/// per-class queue accounting is unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoalesceMode {
    /// One task per envelope — the seed behaviour, bit for bit (default).
    Off,
    /// Coalesce consecutive same-stage tasks (the engine-batching
    /// constraint: a batch must enter the same layers).
    Stage,
    /// Coalesce only same-stage *and* same-class runs, so one envelope
    /// never mixes traffic classes (strictest per-class semantics).
    StageClass,
    /// Same-stage coalescing with an *adaptively sized* run: the offload
    /// policy's [`crate::policy::OffloadPolicy::coalesce_take`] seam
    /// shrinks the drained run from measured link contention (D_nm
    /// inflation over its best-observed floor) — singles on an idle
    /// medium, where pipelined transfers beat one long envelope; runs up
    /// to `coalesce_max` under pressure, where shed headers and saved
    /// contention slots win.
    Adaptive,
}

impl CoalesceMode {
    pub fn parse(name: &str) -> Result<CoalesceMode, String> {
        Ok(match name {
            "off" => CoalesceMode::Off,
            "stage" => CoalesceMode::Stage,
            "stage-class" => CoalesceMode::StageClass,
            "adaptive" => CoalesceMode::Adaptive,
            other => {
                return Err(format!(
                    "unknown coalesce mode {other:?} (off|stage|stage-class|adaptive)"
                ))
            }
        })
    }
}

/// Which queue discipline the worker queues run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DisciplineKind {
    /// Arrival order (the seed behaviour; the paper's implicit choice).
    Fifo,
    /// Strict priority across classes, FIFO within a class.
    StrictPriority,
    /// Earliest deadline first. `drop_late` discards tasks whose deadline
    /// already passed at pop time (counted, never silently lost).
    Edf { drop_late: bool },
    /// Deficit round robin: weighted-fair across classes per
    /// [`SchedConfig::class_quantum`], FIFO within a class.
    WeightedFair,
}

/// Scheduling configuration consumed by the `Run` builder / `WorkerCore`.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedConfig {
    pub discipline: DisciplineKind,
    /// Number of traffic classes; admission stamps classes round-robin
    /// (class 0 = highest priority).
    pub num_classes: u8,
    /// Per-class latency budget (seconds): a task admitted at `t` gets
    /// deadline `t + class_deadline_s[class]`. Only deadline-aware
    /// disciplines read it. Length equals `num_classes` after `validate`.
    pub class_deadline_s: Vec<f64>,
    /// Per-class DRR service quantum (weights; only [`Drr`] reads it).
    /// Length equals `num_classes` after `validate`.
    pub class_quantum: Vec<f64>,
    pub batch: BatchPolicy,
    /// Cross-worker batch coalescing: whether an offload drains a run of
    /// same-stage (same-class) tasks into one wire envelope. `Off` (the
    /// default) reproduces the seed's one-task-per-message wire.
    pub coalesce: CoalesceMode,
    /// Cap on tasks per coalesced envelope (>= 1; irrelevant under
    /// [`CoalesceMode::Off`]).
    pub coalesce_max: usize,
}

impl Default for SchedConfig {
    fn default() -> SchedConfig {
        SchedConfig {
            discipline: DisciplineKind::Fifo,
            num_classes: 1,
            class_deadline_s: vec![1.0],
            class_quantum: vec![1.0],
            batch: BatchPolicy::default(),
            coalesce: CoalesceMode::Off,
            coalesce_max: 8,
        }
    }
}

impl SchedConfig {
    /// Set the class count, broadcasting the last deadline budget and
    /// quantum to any newly added classes.
    pub fn with_classes(mut self, n: u8) -> SchedConfig {
        let n = n.max(1);
        self.num_classes = n;
        let last = self.class_deadline_s.last().copied().unwrap_or(1.0);
        self.class_deadline_s.resize(n as usize, last);
        let last_q = self.class_quantum.last().copied().unwrap_or(1.0);
        self.class_quantum.resize(n as usize, last_q);
        self
    }

    /// Deadline budget for `class` (classes beyond the configured count
    /// inherit the last budget).
    pub fn deadline_for(&self, class: u8) -> f64 {
        self.class_deadline_s
            .get(class as usize)
            .or(self.class_deadline_s.last())
            .copied()
            .unwrap_or(f64::INFINITY)
    }

    /// Build one queue instance of the configured discipline.
    /// `measure_from` is the run's warmup boundary: drops before it are
    /// discarded but excluded from the counters, like every other stat.
    pub fn build_queue(&self, measure_from: f64) -> Box<dyn QueueDiscipline> {
        match self.discipline {
            DisciplineKind::Fifo => Box::new(Fifo::new()),
            DisciplineKind::StrictPriority => {
                Box::new(StrictPriority::new(self.num_classes))
            }
            DisciplineKind::Edf { drop_late } => {
                Box::new(Edf::new(drop_late).measured_from(measure_from))
            }
            DisciplineKind::WeightedFair => {
                Box::new(Drr::new(self.num_classes, self.class_quantum.clone()))
            }
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.num_classes == 0 {
            return Err("num_classes must be >= 1".into());
        }
        if self.class_deadline_s.len() != self.num_classes as usize {
            return Err(format!(
                "class_deadline_s has {} entries for {} classes",
                self.class_deadline_s.len(),
                self.num_classes
            ));
        }
        if self.class_deadline_s.iter().any(|&d| !(d > 0.0)) {
            return Err("class deadline budgets must be positive".into());
        }
        if self.class_quantum.len() != self.num_classes as usize {
            return Err(format!(
                "class_quantum has {} entries for {} classes",
                self.class_quantum.len(),
                self.num_classes
            ));
        }
        if self.class_quantum.iter().any(|&q| !(q > 0.0) || !q.is_finite()) {
            return Err("class quanta must be positive and finite".into());
        }
        if self.batch.max_batch == 0 {
            return Err("max_batch must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.batch.marginal) {
            return Err(format!("batch marginal {} outside [0,1]", self.batch.marginal));
        }
        if self.coalesce_max == 0 {
            return Err("coalesce_max must be >= 1".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_seed_equivalent() {
        let s = SchedConfig::default();
        assert_eq!(s.discipline, DisciplineKind::Fifo);
        assert_eq!(s.num_classes, 1);
        assert_eq!(s.batch.max_batch, 1);
        assert_eq!(s.coalesce, CoalesceMode::Off, "seed wire: one task per message");
        assert!(s.validate().is_ok());
    }

    #[test]
    fn coalesce_mode_parses_and_validates() {
        assert_eq!(CoalesceMode::parse("off").unwrap(), CoalesceMode::Off);
        assert_eq!(CoalesceMode::parse("stage").unwrap(), CoalesceMode::Stage);
        assert_eq!(CoalesceMode::parse("stage-class").unwrap(), CoalesceMode::StageClass);
        assert_eq!(CoalesceMode::parse("adaptive").unwrap(), CoalesceMode::Adaptive);
        assert!(CoalesceMode::parse("warp").is_err());
        let s = SchedConfig { coalesce_max: 0, ..SchedConfig::default() };
        assert!(s.validate().is_err(), "coalesce_max 0 is rejected");
    }

    #[test]
    fn with_classes_broadcasts_deadlines() {
        let s = SchedConfig {
            class_deadline_s: vec![0.25],
            class_quantum: vec![2.0],
            ..SchedConfig::default()
        }
        .with_classes(3);
        assert_eq!(s.class_deadline_s, vec![0.25, 0.25, 0.25]);
        assert_eq!(s.class_quantum, vec![2.0, 2.0, 2.0]);
        assert!((s.deadline_for(1) - 0.25).abs() < 1e-12);
        // classes beyond the configured count inherit the last budget
        assert!((s.deadline_for(9) - 0.25).abs() < 1e-12);
        assert!(s.validate().is_ok());
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        let s = SchedConfig { num_classes: 0, ..SchedConfig::default() };
        assert!(s.validate().is_err());
        let mut s = SchedConfig::default().with_classes(2);
        s.class_deadline_s = vec![1.0]; // one budget for two classes
        assert!(s.validate().is_err());
        let s = SchedConfig {
            batch: BatchPolicy { max_batch: 0, ..BatchPolicy::default() },
            ..SchedConfig::default()
        };
        assert!(s.validate().is_err());
        let s = SchedConfig {
            batch: BatchPolicy { marginal: 1.5, ..BatchPolicy::default() },
            ..SchedConfig::default()
        };
        assert!(s.validate().is_err());
        let s = SchedConfig { class_deadline_s: vec![0.0], ..SchedConfig::default() };
        assert!(s.validate().is_err());
        let mut s = SchedConfig::default().with_classes(2);
        s.class_quantum = vec![1.0]; // one quantum for two classes
        assert!(s.validate().is_err());
        let s = SchedConfig { class_quantum: vec![0.0], ..SchedConfig::default() };
        assert!(s.validate().is_err());
    }

    #[test]
    fn build_queue_matches_kind() {
        for (kind, want_len) in [
            (DisciplineKind::Fifo, 0usize),
            (DisciplineKind::StrictPriority, 0),
            (DisciplineKind::Edf { drop_late: false }, 0),
            (DisciplineKind::WeightedFair, 0),
        ] {
            let cfg = SchedConfig { discipline: kind, ..SchedConfig::default() };
            let q = cfg.build_queue(0.0);
            assert_eq!(q.len(), want_len);
            assert!(q.is_empty());
        }
    }
}
