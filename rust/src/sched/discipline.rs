//! The [`QueueDiscipline`] trait and the FIFO baseline.

use crate::coordinator::queues::TaskQueue;
use crate::coordinator::task::Task;

/// A scheduling discipline for one worker queue (I_n or O_n).
///
/// Contract, relied on by `WorkerCore` and the run reports:
///
/// * `len()` is the live occupancy — the signal Algs 1–4 consume;
/// * `peak()` and `total_enqueued()` are monotone accounting: a
///   [`QueueDiscipline::drain_all`] (churn re-homing) empties the queue but
///   leaves both untouched;
/// * `drain_all()` yields the queued tasks in *arrival order* (push
///   order), regardless of the discipline's service order, so re-homed
///   work replays at the source in the order it was admitted;
/// * `pop_next(now)` may return `None` while `len() > 0` only transiently
///   (a deadline-aware discipline aging out every remaining task), never
///   lose a task silently: anything discarded shows up in
///   [`QueueDiscipline::dropped_per_class`].
pub trait QueueDiscipline: std::fmt::Debug + Send {
    /// Enqueue a task.
    fn push(&mut self, t: Task);

    /// Dequeue the task the discipline schedules next. `now` lets
    /// deadline-aware disciplines age out expired tasks at pop time.
    fn pop_next(&mut self, now: f64) -> Option<Task>;

    /// Discard everything the discipline would age out at `now`, so a
    /// following `peek` is truthful about what `pop_next` will return
    /// (batch formation relies on this). No-op for disciplines that never
    /// discard.
    fn expire(&mut self, _now: f64) {}

    /// The task `pop_next` would serve next (ignoring age-out; call
    /// [`QueueDiscipline::expire`] first when that matters).
    fn peek(&self) -> Option<&Task>;

    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Peak occupancy ever observed (report accounting; never reset).
    fn peak(&self) -> usize;

    /// Total tasks ever pushed (report accounting; never reset).
    fn total_enqueued(&self) -> u64;

    /// Live occupancy of one traffic class.
    fn class_len(&self, class: u8) -> usize;

    /// Tasks discarded by the discipline per class (EDF `drop_late`);
    /// empty for disciplines that never discard.
    fn dropped_per_class(&self) -> &[u64] {
        &[]
    }

    /// Tasks served (popped) per class; empty for disciplines that do not
    /// track it. Weighted-fair disciplines expose their service split here
    /// so the report can show what each class actually received.
    fn served_per_class(&self) -> &[u64] {
        &[]
    }

    /// Earliest absolute deadline among queued tasks (`None` when empty).
    /// Cold path: deadline-aware gossip reads it once per gossip tick.
    fn earliest_deadline(&self) -> Option<f64>;

    /// How many queued tasks `pop_next` would serve consecutively that
    /// share the head task's stage (and its traffic class when
    /// `same_class`), capped at `max` — the run an offload could coalesce
    /// into one wire envelope. This is a *hint* for offload policies
    /// weighing batch size, and it bounds the drain; it may be
    /// approximate in either direction (disciplines without a cheap
    /// service-order walk probe a bounded sample) — the actual envelope
    /// is formed by popping with a per-pop re-check, so an estimate never
    /// puts a mismatched task in a batch. The default is the safe lower
    /// bound: the head alone. 0 when empty.
    fn coalescible_run(&self, max: usize, _same_class: bool) -> usize {
        if self.is_empty() || max == 0 {
            0
        } else {
            1
        }
    }

    /// Remove every queued task, in arrival order. Peak/total accounting
    /// is preserved (the drain is churn bookkeeping, not service).
    fn drain_all(&mut self) -> Vec<Task>;
}

/// Live per-class occupancy counters shared by the disciplines.
#[derive(Debug, Default, Clone)]
pub(crate) struct ClassCounts(Vec<usize>);

impl ClassCounts {
    pub(crate) fn add(&mut self, class: u8) {
        let i = class as usize;
        if self.0.len() <= i {
            self.0.resize(i + 1, 0);
        }
        self.0[i] += 1;
    }

    pub(crate) fn sub(&mut self, class: u8) {
        let i = class as usize;
        debug_assert!(self.0.get(i).is_some_and(|&c| c > 0), "class {i} count underflow");
        if let Some(c) = self.0.get_mut(i) {
            *c = c.saturating_sub(1);
        }
    }

    pub(crate) fn get(&self, class: u8) -> usize {
        self.0.get(class as usize).copied().unwrap_or(0)
    }

    pub(crate) fn clear(&mut self) {
        self.0.iter_mut().for_each(|c| *c = 0);
    }
}

/// First-in-first-out — the seed's `TaskQueue` behaviour, bit for bit:
/// push/pop carry zero extra bookkeeping (they are the benchmarked hot
/// path); per-class occupancy is a cold-path scan.
#[derive(Debug, Default)]
pub struct Fifo {
    q: TaskQueue,
}

impl Fifo {
    pub fn new() -> Fifo {
        Fifo::default()
    }
}

impl QueueDiscipline for Fifo {
    fn push(&mut self, t: Task) {
        self.q.push(t);
    }

    fn pop_next(&mut self, _now: f64) -> Option<Task> {
        self.q.pop()
    }

    fn peek(&self) -> Option<&Task> {
        self.q.peek()
    }

    fn len(&self) -> usize {
        self.q.len()
    }

    fn peak(&self) -> usize {
        self.q.peak()
    }

    fn total_enqueued(&self) -> u64 {
        self.q.total_enqueued()
    }

    fn class_len(&self, class: u8) -> usize {
        self.q.iter().filter(|t| t.class == class).count()
    }

    fn earliest_deadline(&self) -> Option<f64> {
        self.q.iter().map(|t| t.deadline).min_by(f64::total_cmp)
    }

    fn coalescible_run(&self, max: usize, same_class: bool) -> usize {
        // FIFO service order IS iteration order: the run is exact.
        let mut it = self.q.iter();
        let Some(head) = it.next() else { return 0 };
        let mut run = 1;
        for t in it {
            if run >= max
                || t.stage != head.stage
                || (same_class && t.class != head.class)
            {
                break;
            }
            run += 1;
        }
        run.min(max)
    }

    fn drain_all(&mut self) -> Vec<Task> {
        self.q.drain_all()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(id: u64, class: u8) -> Task {
        Task { class, ..Task::initial(id, id as usize, None, id as f64) }
    }

    #[test]
    fn fifo_serves_in_arrival_order() {
        let mut q = Fifo::new();
        q.push(task(1, 1));
        q.push(task(2, 0));
        q.push(task(3, 1));
        assert_eq!(q.peek().unwrap().id, 1);
        assert_eq!(q.pop_next(0.0).unwrap().id, 1);
        assert_eq!(q.pop_next(0.0).unwrap().id, 2);
        assert_eq!(q.pop_next(0.0).unwrap().id, 3);
        assert!(q.pop_next(0.0).is_none());
    }

    #[test]
    fn fifo_accounting_matches_seed_taskqueue() {
        let mut q = Fifo::new();
        for i in 0..5 {
            q.push(task(i, (i % 2) as u8));
        }
        q.pop_next(0.0);
        q.pop_next(0.0);
        q.push(task(9, 1));
        assert_eq!(q.len(), 4);
        assert_eq!(q.peak(), 5);
        assert_eq!(q.total_enqueued(), 6);
        assert_eq!(q.class_len(0), 2); // ids 2, 4
        assert_eq!(q.class_len(1), 2); // ids 3, 9
        assert!(q.dropped_per_class().is_empty());
    }

    #[test]
    fn fifo_coalescible_run_counts_the_head_run_exactly() {
        let mut q = Fifo::new();
        let st = |id: u64, stage: usize, class: u8| Task {
            stage,
            class,
            ..Task::initial(id, id as usize, None, 0.0)
        };
        assert_eq!(q.coalescible_run(8, false), 0, "empty queue has no run");
        q.push(st(1, 2, 0));
        q.push(st(2, 2, 1));
        q.push(st(3, 2, 0));
        q.push(st(4, 1, 0)); // stage boundary
        q.push(st(5, 2, 0));
        assert_eq!(q.coalescible_run(8, false), 3, "run stops at the stage boundary");
        assert_eq!(q.coalescible_run(2, false), 2, "capped at max");
        assert_eq!(q.coalescible_run(8, true), 1, "class boundary after the head");
    }

    #[test]
    fn fifo_drain_preserves_arrival_order_and_accounting() {
        let mut q = Fifo::new();
        for i in 0..4 {
            q.push(task(i, 0));
        }
        let peak = q.peak();
        let total = q.total_enqueued();
        let drained = q.drain_all();
        let ids: Vec<u64> = drained.iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3], "drain must preserve arrival order");
        assert_eq!(q.len(), 0);
        assert_eq!(q.class_len(0), 0);
        assert_eq!(q.peak(), peak, "drain must not reset peak");
        assert_eq!(q.total_enqueued(), total, "drain must not reset total_enqueued");
    }
}
