//! Same-stage batch formation for `StartCompute` (the DEFER insight:
//! amortize the fixed per-stage dispatch cost over several tasks).

use super::discipline::QueueDiscipline;
use crate::coordinator::task::Task;

/// How `WorkerCore` groups queued tasks into one engine call.
///
/// A batch is always *same-stage*: the engine runs one batched forward of
/// stage k, so every element must enter the same layers. The policy pops
/// the discipline's head task, then keeps popping while the next scheduled
/// task is at the same stage, up to `max_batch`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchPolicy {
    /// Maximum tasks per `StartCompute` (1 = unbatched, the seed behaviour).
    pub max_batch: usize,
    /// Marginal cost of each extra task in a batch, as a fraction of the
    /// stage cost: a batch of b costs `stage_cost * (1 + (b-1) * marginal)`.
    /// 0 models a fully dispatch-bound stage; 1 disables amortization.
    pub marginal: f64,
}

impl Default for BatchPolicy {
    fn default() -> BatchPolicy {
        BatchPolicy { max_batch: 1, marginal: 0.25 }
    }
}

impl BatchPolicy {
    /// Unbatched (identical to the seed's one-task-at-a-time hot path).
    pub fn unbatched() -> BatchPolicy {
        BatchPolicy::default()
    }

    /// Batch up to `n` same-stage tasks with the default marginal cost.
    pub fn batched(n: usize) -> BatchPolicy {
        BatchPolicy { max_batch: n.max(1), ..BatchPolicy::default() }
    }

    /// Pop a same-stage batch off `q`. Empty only if `q` yields nothing
    /// (e.g. EDF `drop_late` aged out every queued task). Expired work is
    /// discarded up front so `peek` is truthful during formation — a
    /// re-push here would double-count `total_enqueued`.
    pub fn form(&self, q: &mut dyn QueueDiscipline, now: f64) -> Vec<Task> {
        q.expire(now);
        let mut batch = Vec::new();
        let Some(first) = q.pop_next(now) else {
            return batch;
        };
        let stage = first.stage;
        batch.push(first);
        while batch.len() < self.max_batch {
            match q.peek() {
                Some(t) if t.stage == stage => {
                    batch.push(q.pop_next(now).expect("peeked task"));
                }
                _ => break,
            }
        }
        batch
    }

    /// Virtual compute cost of a batch of `batch_len` tasks at a stage
    /// whose single-task cost is `stage_cost_s`.
    pub fn batch_cost(&self, stage_cost_s: f64, batch_len: usize) -> f64 {
        stage_cost_s * (1.0 + (batch_len.saturating_sub(1)) as f64 * self.marginal)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Edf, Fifo};
    use super::*;

    fn task(id: u64, stage: usize) -> Task {
        Task { stage, ..Task::initial(id, id as usize, None, 0.0) }
    }

    #[test]
    fn forms_same_stage_run_up_to_max() {
        let mut q = Fifo::new();
        for i in 0..3 {
            q.push(task(i, 1));
        }
        q.push(task(3, 2));
        q.push(task(4, 1));
        let b = BatchPolicy::batched(8).form(&mut q, 0.0);
        let ids: Vec<u64> = b.iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![0, 1, 2], "stops at the stage boundary");
        assert_eq!(q.len(), 2);
        let b = BatchPolicy::batched(8).form(&mut q, 0.0);
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].stage, 2);
    }

    #[test]
    fn max_batch_caps_the_run() {
        let mut q = Fifo::new();
        for i in 0..6 {
            q.push(task(i, 1));
        }
        let b = BatchPolicy::batched(4).form(&mut q, 0.0);
        assert_eq!(b.len(), 4);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn unbatched_pops_exactly_one() {
        let mut q = Fifo::new();
        q.push(task(0, 1));
        q.push(task(1, 1));
        let b = BatchPolicy::unbatched().form(&mut q, 0.0);
        assert_eq!(b.len(), 1);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn empty_queue_forms_empty_batch() {
        let mut q = Fifo::new();
        assert!(BatchPolicy::batched(4).form(&mut q, 0.0).is_empty());
    }

    #[test]
    fn edf_age_out_mid_batch_is_safe() {
        // Expired work is discarded before formation, so the peeked stage
        // is always the popped stage and no task is ever re-pushed (which
        // would double-count total_enqueued).
        let mut q = Edf::new(true);
        q.push(Task { stage: 1, deadline: 10.0, ..Task::initial(1, 1, None, 0.0) });
        q.push(Task { stage: 1, deadline: 1.0, ..Task::initial(2, 2, None, 0.0) });
        q.push(Task { stage: 2, deadline: 20.0, ..Task::initial(3, 3, None, 0.0) });
        // now = 5: task 2 (deadline 1) expires up front; task 1 (stage 1)
        // heads the batch; task 3 (stage 2) stops it.
        let b = BatchPolicy::batched(4).form(&mut q, 5.0);
        let ids: Vec<u64> = b.iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![1]);
        assert_eq!(q.len(), 1, "stage-2 task still queued");
        assert_eq!(q.total_enqueued(), 3, "formation must not re-count pushes");
        assert_eq!(q.dropped_per_class(), &[1u64][..]);
    }

    #[test]
    fn batch_cost_amortizes_marginal() {
        let p = BatchPolicy { max_batch: 8, marginal: 0.25 };
        assert!((p.batch_cost(0.004, 1) - 0.004).abs() < 1e-12);
        assert!((p.batch_cost(0.004, 5) - 0.004 * 2.0).abs() < 1e-12);
        // per-task cost falls with batch size
        assert!(p.batch_cost(0.004, 8) / 8.0 < 0.004 / 2.0);
    }
}
