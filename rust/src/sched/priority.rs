//! Class- and deadline-aware disciplines: [`StrictPriority`] and [`Edf`].

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use super::discipline::{ClassCounts, QueueDiscipline};
use crate::coordinator::task::Task;

/// Strict priority across N traffic classes (class 0 served first), FIFO
/// within a class — the per-worker queueing of Priority-Aware MDI
/// (arXiv 2412.12371). An arrival sequence number is stamped at push so
/// `drain_all` can restore global arrival order across lanes.
#[derive(Debug)]
pub struct StrictPriority {
    /// One FIFO lane per class; tasks with `class >= num_classes` land in
    /// the last (lowest-priority) lane.
    lanes: Vec<VecDeque<(u64, Task)>>,
    seq: u64,
    len: usize,
    peak: usize,
    total_enqueued: u64,
}

impl StrictPriority {
    pub fn new(num_classes: u8) -> StrictPriority {
        StrictPriority {
            lanes: (0..num_classes.max(1)).map(|_| VecDeque::new()).collect(),
            seq: 0,
            len: 0,
            peak: 0,
            total_enqueued: 0,
        }
    }

    fn lane_of(&self, class: u8) -> usize {
        (class as usize).min(self.lanes.len() - 1)
    }
}

impl QueueDiscipline for StrictPriority {
    fn push(&mut self, t: Task) {
        self.seq += 1;
        let lane = self.lane_of(t.class);
        self.lanes[lane].push_back((self.seq, t));
        self.len += 1;
        self.peak = self.peak.max(self.len);
        self.total_enqueued += 1;
    }

    fn pop_next(&mut self, _now: f64) -> Option<Task> {
        let lane = self.lanes.iter_mut().find(|l| !l.is_empty())?;
        let (_, t) = lane.pop_front().expect("non-empty lane");
        self.len -= 1;
        Some(t)
    }

    fn peek(&self) -> Option<&Task> {
        self.lanes.iter().find_map(|l| l.front()).map(|(_, t)| t)
    }

    fn len(&self) -> usize {
        self.len
    }

    fn peak(&self) -> usize {
        self.peak
    }

    fn total_enqueued(&self) -> u64 {
        self.total_enqueued
    }

    fn class_len(&self, class: u8) -> usize {
        if (class as usize) < self.lanes.len() {
            // Exact for in-range classes; clamped classes share the last
            // lane, where class identity is kept on the task itself.
            self.lanes[class as usize].iter().filter(|(_, t)| t.class == class).count()
        } else {
            0
        }
    }

    fn earliest_deadline(&self) -> Option<f64> {
        self.lanes
            .iter()
            .flat_map(|l| l.iter().map(|(_, t)| t.deadline))
            .min_by(f64::total_cmp)
    }

    fn coalescible_run(&self, max: usize, same_class: bool) -> usize {
        if max == 0 {
            return 0;
        }
        // Service order is exact: lanes in priority order, FIFO within.
        let mut head: Option<&Task> = None;
        let mut run = 0;
        for lane in &self.lanes {
            for (_, t) in lane {
                match head {
                    None => head = Some(t),
                    Some(h) => {
                        if t.stage != h.stage || (same_class && t.class != h.class) {
                            return run;
                        }
                    }
                }
                run += 1;
                if run >= max {
                    return run;
                }
            }
        }
        run
    }

    fn drain_all(&mut self) -> Vec<Task> {
        let mut all: Vec<(u64, Task)> =
            self.lanes.iter_mut().flat_map(|l| l.drain(..)).collect();
        all.sort_by_key(|(seq, _)| *seq);
        self.len = 0;
        all.into_iter().map(|(_, t)| t).collect()
    }
}

/// Heap entry ordered earliest-deadline-first (ties broken by arrival).
#[derive(Debug)]
struct EdfEntry {
    deadline: f64,
    seq: u64,
    task: Task,
}

impl PartialEq for EdfEntry {
    fn eq(&self, o: &Self) -> bool {
        self.deadline == o.deadline && self.seq == o.seq
    }
}
impl Eq for EdfEntry {}
impl PartialOrd for EdfEntry {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for EdfEntry {
    fn cmp(&self, o: &Self) -> Ordering {
        // BinaryHeap is a max-heap: reverse for earliest-deadline-first.
        o.deadline.total_cmp(&self.deadline).then(o.seq.cmp(&self.seq))
    }
}

/// Earliest-deadline-first. Deadlines are stamped at admission from the
/// per-class budget in [`super::SchedConfig`]; `drop_late` discards tasks
/// whose deadline already passed at pop time (a late inference result is
/// worthless to a realtime client — better to spend the compute on one
/// that can still meet its budget). Drops are counted per class.
#[derive(Debug)]
pub struct Edf {
    heap: BinaryHeap<EdfEntry>,
    seq: u64,
    peak: usize,
    total_enqueued: u64,
    classes: ClassCounts,
    drop_late: bool,
    dropped: Vec<u64>,
    /// Drops before this time are discarded but not *counted*, matching
    /// how every other outcome counter excludes the warmup window.
    measure_from: f64,
}

impl Edf {
    pub fn new(drop_late: bool) -> Edf {
        Edf {
            heap: BinaryHeap::new(),
            seq: 0,
            peak: 0,
            total_enqueued: 0,
            classes: ClassCounts::default(),
            drop_late,
            dropped: Vec::new(),
            measure_from: 0.0,
        }
    }

    /// Exclude drops before `t` from the counters (the run's warmup).
    pub fn measured_from(mut self, t: f64) -> Edf {
        self.measure_from = t;
        self
    }

    fn note_drop(&mut self, class: u8, now: f64) {
        if now < self.measure_from {
            return;
        }
        let i = class as usize;
        if self.dropped.len() <= i {
            self.dropped.resize(i + 1, 0);
        }
        self.dropped[i] += 1;
    }
}

impl QueueDiscipline for Edf {
    fn push(&mut self, t: Task) {
        self.seq += 1;
        self.classes.add(t.class);
        self.heap.push(EdfEntry { deadline: t.deadline, seq: self.seq, task: t });
        self.peak = self.peak.max(self.heap.len());
        self.total_enqueued += 1;
    }

    fn pop_next(&mut self, now: f64) -> Option<Task> {
        while let Some(e) = self.heap.pop() {
            self.classes.sub(e.task.class);
            if self.drop_late && e.deadline < now {
                self.note_drop(e.task.class, now);
                continue;
            }
            return Some(e.task);
        }
        None
    }

    fn expire(&mut self, now: f64) {
        if !self.drop_late {
            return;
        }
        while let Some(top) = self.heap.peek() {
            if top.deadline >= now {
                break;
            }
            let e = self.heap.pop().expect("peeked entry");
            self.classes.sub(e.task.class);
            self.note_drop(e.task.class, now);
        }
    }

    fn peek(&self) -> Option<&Task> {
        self.heap.peek().map(|e| &e.task)
    }

    fn len(&self) -> usize {
        self.heap.len()
    }

    fn peak(&self) -> usize {
        self.peak
    }

    fn total_enqueued(&self) -> u64 {
        self.total_enqueued
    }

    fn class_len(&self, class: u8) -> usize {
        self.classes.get(class)
    }

    fn dropped_per_class(&self) -> &[u64] {
        &self.dropped
    }

    fn earliest_deadline(&self) -> Option<f64> {
        // The EDF heap's top *is* the earliest deadline.
        self.heap.peek().map(|e| e.deadline)
    }

    fn coalescible_run(&self, max: usize, same_class: bool) -> usize {
        // The heap is not iterable in service (deadline) order without a
        // sort; estimate instead: when a bounded probe of the queue looks
        // uniform (every sampled task matches the head — e.g. a
        // stage-heavy backlog), report the full run, else the safe lower
        // bound. The probe cap keeps this off the O(n)-per-offload path
        // on deep backlogs; the estimate only prices the envelope — the
        // drain itself re-checks every pop, so an optimistic hint can
        // never put a mismatched task in a batch.
        const PROBE: usize = 64;
        let Some(top) = self.heap.peek() else { return 0 };
        let (stage, class) = (top.task.stage, top.task.class);
        let uniform = self
            .heap
            .iter()
            .take(PROBE)
            .all(|e| e.task.stage == stage && (!same_class || e.task.class == class));
        if uniform {
            self.heap.len().min(max)
        } else {
            1.min(max)
        }
    }

    fn drain_all(&mut self) -> Vec<Task> {
        let mut all: Vec<EdfEntry> = std::mem::take(&mut self.heap).into_vec();
        all.sort_by_key(|e| e.seq);
        self.classes.clear();
        all.into_iter().map(|e| e.task).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(id: u64, class: u8, deadline: f64) -> Task {
        Task { class, deadline, ..Task::initial(id, id as usize, None, 0.0) }
    }

    #[test]
    fn strict_priority_serves_lower_class_first_fifo_within() {
        let mut q = StrictPriority::new(3);
        q.push(task(1, 2, 1.0));
        q.push(task(2, 0, 1.0));
        q.push(task(3, 1, 1.0));
        q.push(task(4, 0, 1.0));
        assert_eq!(q.peek().unwrap().id, 2);
        let order: Vec<u64> =
            std::iter::from_fn(|| q.pop_next(0.0)).map(|t| t.id).collect();
        assert_eq!(order, vec![2, 4, 3, 1]);
    }

    #[test]
    fn strict_priority_clamps_out_of_range_classes() {
        let mut q = StrictPriority::new(2);
        q.push(task(1, 9, 1.0)); // lands in the last lane
        q.push(task(2, 0, 1.0));
        assert_eq!(q.pop_next(0.0).unwrap().id, 2);
        assert_eq!(q.pop_next(0.0).unwrap().id, 1);
        assert_eq!(q.class_len(9), 0, "clamped classes report 0 beyond lanes");
    }

    #[test]
    fn strict_priority_drain_restores_arrival_order() {
        let mut q = StrictPriority::new(2);
        q.push(task(1, 1, 1.0));
        q.push(task(2, 0, 1.0));
        q.push(task(3, 1, 1.0));
        let peak = q.peak();
        let total = q.total_enqueued();
        let ids: Vec<u64> = q.drain_all().iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![1, 2, 3], "arrival order, not service order");
        assert_eq!(q.len(), 0);
        assert_eq!(q.peak(), peak);
        assert_eq!(q.total_enqueued(), total);
    }

    #[test]
    fn strict_priority_occupancy_accounting() {
        let mut q = StrictPriority::new(2);
        for i in 0..5 {
            q.push(task(i, (i % 2) as u8, 1.0));
        }
        q.pop_next(0.0);
        assert_eq!(q.len(), 4);
        assert_eq!(q.peak(), 5);
        assert_eq!(q.total_enqueued(), 5);
        assert_eq!(q.class_len(0), 2);
        assert_eq!(q.class_len(1), 2);
    }

    #[test]
    fn edf_serves_earliest_deadline_first() {
        let mut q = Edf::new(false);
        q.push(task(1, 0, 3.0));
        q.push(task(2, 0, 1.0));
        q.push(task(3, 0, 2.0));
        assert_eq!(q.peek().unwrap().id, 2);
        let order: Vec<u64> =
            std::iter::from_fn(|| q.pop_next(0.0)).map(|t| t.id).collect();
        assert_eq!(order, vec![2, 3, 1]);
    }

    #[test]
    fn edf_ties_break_by_arrival() {
        let mut q = Edf::new(false);
        q.push(task(1, 0, 1.0));
        q.push(task(2, 0, 1.0));
        assert_eq!(q.pop_next(0.0).unwrap().id, 1);
        assert_eq!(q.pop_next(0.0).unwrap().id, 2);
    }

    #[test]
    fn edf_without_drop_late_serves_expired_tasks() {
        let mut q = Edf::new(false);
        q.push(task(1, 0, 1.0));
        assert_eq!(q.pop_next(5.0).unwrap().id, 1);
        assert!(q.dropped_per_class().is_empty());
    }

    #[test]
    fn edf_drop_late_ages_out_expired_and_counts() {
        let mut q = Edf::new(true);
        q.push(task(1, 0, 1.0)); // expired at now = 2
        q.push(task(2, 1, 5.0)); // still live
        assert_eq!(q.pop_next(2.0).unwrap().id, 2);
        assert_eq!(q.dropped_per_class(), &[1u64][..]);
        assert_eq!(q.len(), 0);
        assert_eq!(q.class_len(0), 0);
        // everything expired: pop drains and returns None
        q.push(task(3, 1, 1.0));
        assert!(q.pop_next(9.0).is_none());
        assert_eq!(q.dropped_per_class(), &[1u64, 1][..]);
    }

    #[test]
    fn edf_warmup_drops_are_discarded_but_not_counted() {
        let mut q = Edf::new(true).measured_from(10.0);
        q.push(task(1, 0, 1.0));
        assert!(q.pop_next(5.0).is_none(), "expired task still discarded");
        assert!(q.dropped_per_class().is_empty(), "warmup drops not counted");
        q.push(task(2, 0, 11.0));
        assert!(q.pop_next(12.0).is_none());
        assert_eq!(q.dropped_per_class(), &[1u64][..], "in-window drops counted");
    }

    #[test]
    fn edf_expire_discards_everything_late_and_nothing_else() {
        let mut q = Edf::new(true);
        q.push(task(1, 0, 1.0));
        q.push(task(2, 1, 2.0));
        q.push(task(3, 0, 9.0));
        q.expire(3.0);
        assert_eq!(q.len(), 1);
        assert_eq!(q.dropped_per_class(), &[1u64, 1][..]);
        assert_eq!(q.peek().unwrap().id, 3, "peek is truthful after expire");
        // without drop_late, expire is a no-op
        let mut q = Edf::new(false);
        q.push(task(1, 0, 1.0));
        q.expire(3.0);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn edf_drain_restores_arrival_order_keeps_accounting() {
        let mut q = Edf::new(true);
        q.push(task(1, 0, 9.0));
        q.push(task(2, 0, 1.0));
        q.push(task(3, 0, 4.0));
        let ids: Vec<u64> = q.drain_all().iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
        assert_eq!(q.peak(), 3);
        assert_eq!(q.total_enqueued(), 3);
        assert_eq!(q.len(), 0);
    }
}
