//! An engine-free [`InferenceEngine`] with REAL feature tensors and a
//! deterministic autoencoder — the test substrate for the zero-copy wire
//! and the batched-AE seam.
//!
//! [`crate::runtime::sim_engine::SimEngine`] replays oracle confidences
//! but produces `features: None`, so every sender-side encode is
//! *virtual* and the AE fallback/recharge machinery never runs under it.
//! [`TensorEngine`] replays the same oracle table **and** materializes a
//! deterministic feature tensor per (sample, stage), so full runs on
//! either driver exercise the physical path: views travel the queues, the
//! AE encodes real tensors (average-pool by `pool`, decode repeats — a
//! fixed, engine-independent reconstruction error), and failure injection
//! covers the mid-batch fallback:
//!
//! * [`TensorEngine::declining`] — the AE declines (`Ok(None)`) the given
//!   samples, which then ship raw and re-charge the wire;
//! * [`TensorEngine::declining_all`] — every encode declines;
//! * [`TensorEngine::erroring`] — the whole encoder call fails (`Err`).
//!
//! Encoder invocations are counted ([`TensorEngine::batch_forwards`],
//! [`TensorEngine::single_encodes`]) so tests can assert that k coalesced
//! tensors ride ONE batched forward.
//!
//! The first element of every feature tensor is the sample id, which is
//! how the encoder recovers the sample for failure injection — and how a
//! test can tell whose payload it is looking at.

use std::cell::Cell;
use std::collections::HashSet;

use anyhow::{bail, ensure, Result};

use crate::dataset::ExitTable;
use crate::runtime::{InferenceEngine, StageOutput};
use crate::tensor::Tensor;

/// Oracle-replay engine with real tensors and a pooling autoencoder.
#[derive(Debug)]
pub struct TensorEngine {
    table: ExitTable,
    /// Elements of every inter-stage feature tensor (divisible by `pool`).
    feat: usize,
    /// AE pooling factor: code length is `feat / pool`.
    pool: usize,
    declined: HashSet<usize>,
    decline_all: bool,
    error_encodes: bool,
    batch_forwards: Cell<usize>,
    single_encodes: Cell<usize>,
}

impl TensorEngine {
    pub fn new(table: ExitTable, feat: usize, pool: usize) -> TensorEngine {
        assert!(pool >= 1, "pool factor must be >= 1");
        assert!(feat >= pool && feat % pool == 0, "feat {feat} not divisible by pool {pool}");
        TensorEngine {
            table,
            feat,
            pool,
            declined: HashSet::new(),
            decline_all: false,
            error_encodes: false,
            batch_forwards: Cell::new(0),
            single_encodes: Cell::new(0),
        }
    }

    /// The AE declines (`Ok(None)`) tensors of these samples: they ship
    /// raw and the sender re-charges the wire.
    pub fn declining(mut self, samples: impl IntoIterator<Item = usize>) -> TensorEngine {
        self.declined.extend(samples);
        self
    }

    /// Every encode declines — the run behaves byte-for-byte like a run
    /// without an AE, which is exactly what the recharge tests assert.
    pub fn declining_all(mut self) -> TensorEngine {
        self.decline_all = true;
        self
    }

    /// The whole encoder call errors (`Err`): the entire batch ships raw.
    pub fn erroring(mut self) -> TensorEngine {
        self.error_encodes = true;
        self
    }

    /// How many batched encoder forwards ran ([`InferenceEngine::encode_batch`]).
    pub fn batch_forwards(&self) -> usize {
        self.batch_forwards.get()
    }

    /// How many per-tensor encodes ran ([`InferenceEngine::encode`]).
    pub fn single_encodes(&self) -> usize {
        self.single_encodes.get()
    }

    /// The deterministic feature tensor entering the stage after `sample`'s
    /// current one: element 0 is the sample id, the rest a fixed pattern.
    pub fn features_for(&self, sample: usize) -> Tensor {
        let mut data = Vec::with_capacity(self.feat);
        data.push(sample as f32);
        for i in 1..self.feat {
            data.push(((sample * 31 + i * 7) % 17) as f32 * 0.25 - 2.0);
        }
        Tensor::new(vec![self.feat], data)
    }

    fn encode_one(&self, features: &Tensor) -> Result<Option<Tensor>> {
        if self.error_encodes {
            bail!("injected encoder failure");
        }
        let data = features.data();
        let sample = data.first().copied().unwrap_or(0.0) as usize;
        if self.decline_all || self.declined.contains(&sample) {
            return Ok(None);
        }
        let code: Vec<f32> = data
            .chunks(self.pool)
            .map(|c| c.iter().sum::<f32>() / c.len() as f32)
            .collect();
        Ok(Some(Tensor::new(vec![code.len()], code)))
    }
}

impl InferenceEngine for TensorEngine {
    fn num_stages(&self) -> usize {
        self.table.num_exits
    }

    fn run_stage(
        &self,
        k: usize,
        sample: usize,
        _features: Option<&Tensor>,
    ) -> Result<StageOutput> {
        let exits = self.table.num_exits;
        ensure!(k >= 1 && k <= exits, "stage {k} out of 1..={exits}");
        ensure!(sample < self.table.n, "sample {sample} out of table ({})", self.table.n);
        let features = if k < exits { Some(self.features_for(sample)) } else { None };
        Ok(StageOutput {
            features,
            confidence: self.table.confidence(sample, k - 1),
            prediction: self.table.prediction(sample, k - 1),
        })
    }

    fn encode(&self, features: &Tensor) -> Result<Option<Tensor>> {
        self.single_encodes.set(self.single_encodes.get() + 1);
        self.encode_one(features)
    }

    fn encode_batch(&self, features: &[&Tensor]) -> Result<Vec<Option<Tensor>>> {
        self.batch_forwards.set(self.batch_forwards.get() + 1);
        features.iter().map(|f| self.encode_one(f)).collect()
    }

    fn decode(&self, code: &Tensor) -> Result<Option<Tensor>> {
        let mut out = Vec::with_capacity(code.numel() * self.pool);
        for &v in code.data() {
            for _ in 0..self.pool {
                out.push(v);
            }
        }
        Ok(Some(Tensor::new(vec![out.len()], out)))
    }

    fn has_autoencoder(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> ExitTable {
        ExitTable::synthetic(4, 2, vec![0.9; 8], vec![1; 8])
    }

    #[test]
    fn stages_replay_the_table_with_real_features() {
        let eng = TensorEngine::new(table(), 16, 4);
        let out = eng.run_stage(1, 2, None).unwrap();
        let f = out.features.expect("mid-pipeline stage produces features");
        assert_eq!(f.numel(), 16);
        assert_eq!(f.data()[0], 2.0, "element 0 carries the sample id");
        assert!((out.confidence - 0.9).abs() < 1e-6);
        assert!(eng.run_stage(2, 0, None).unwrap().features.is_none(), "final stage");
        assert!(eng.run_stage(3, 0, None).is_err());
    }

    #[test]
    fn encode_pools_and_decode_repeats() {
        let eng = TensorEngine::new(table(), 8, 4);
        let f = Tensor::new(vec![8], vec![0.0, 4.0, 0.0, 4.0, 1.0, 1.0, 3.0, 3.0]);
        let code = eng.encode(&f).unwrap().expect("encodes");
        assert_eq!(code.data(), &[2.0, 2.0]);
        let rec = eng.decode(&code).unwrap().expect("decodes");
        assert_eq!(rec.numel(), 8);
        assert_eq!(rec.data()[0], 2.0);
        assert_eq!(eng.single_encodes(), 1);
        assert_eq!(eng.batch_forwards(), 0);
    }

    #[test]
    fn failure_injection_declines_and_errors() {
        let eng = TensorEngine::new(table(), 8, 2).declining([3]);
        assert!(eng.encode(&eng.features_for(3)).unwrap().is_none(), "sample 3 declines");
        assert!(eng.encode(&eng.features_for(1)).unwrap().is_some());
        let eng = TensorEngine::new(table(), 8, 2).declining_all();
        assert!(eng.encode(&eng.features_for(1)).unwrap().is_none());
        let eng = TensorEngine::new(table(), 8, 2).erroring();
        assert!(eng.encode(&eng.features_for(1)).is_err());
        assert!(eng.encode_batch(&[&eng.features_for(1)]).is_err());
    }

    #[test]
    fn batch_encode_counts_one_forward() {
        let eng = TensorEngine::new(table(), 8, 2);
        let (a, b) = (eng.features_for(0), eng.features_for(1));
        let codes = eng.encode_batch(&[&a, &b]).unwrap();
        assert_eq!(codes.len(), 2);
        assert!(codes.iter().all(|c| c.is_some()));
        assert_eq!(eng.batch_forwards(), 1);
        assert_eq!(eng.single_encodes(), 0);
    }
}
