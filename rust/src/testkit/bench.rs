//! Mini benchmark harness (criterion substitute — offline image).
//!
//! `cargo bench` targets are `harness = false` binaries that build a
//! [`BenchSuite`], register closures, and print a fixed-width table with
//! mean / p50 / p95 over timed iterations plus a warmup phase. Figure
//! benches additionally print the paper-shaped result rows themselves.

use std::time::{Duration, Instant};

use crate::util::stats::Samples;

/// One measured benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

/// Timing harness: warmup, then fixed-count timed iterations.
pub struct BenchSuite {
    title: String,
    warmup: u32,
    iters: u32,
    results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(title: &str) -> BenchSuite {
        BenchSuite { title: title.to_string(), warmup: 3, iters: 10, results: Vec::new() }
    }

    pub fn warmup(mut self, n: u32) -> Self {
        self.warmup = n;
        self
    }

    pub fn iters(mut self, n: u32) -> Self {
        self.iters = n;
        self
    }

    /// Time `f` (whole-call granularity) and record under `name`.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Samples::new();
        let mut min = f64::INFINITY;
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            let dt = t0.elapsed().as_secs_f64();
            samples.push(dt);
            min = min.min(dt);
        }
        let r = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_s: samples.mean(),
            p50_s: samples.p50(),
            p95_s: samples.p95(),
            min_s: min,
        };
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Time a micro-op by running `inner_iters` calls per sample (for
    /// sub-microsecond operations); reports per-call times.
    pub fn bench_micro<F: FnMut()>(&mut self, name: &str, inner_iters: u32, mut f: F)
        -> &BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Samples::new();
        let mut min = f64::INFINITY;
        for _ in 0..self.iters {
            let t0 = Instant::now();
            for _ in 0..inner_iters {
                f();
            }
            let dt = t0.elapsed().as_secs_f64() / inner_iters as f64;
            samples.push(dt);
            min = min.min(dt);
        }
        let r = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_s: samples.mean(),
            p50_s: samples.p50(),
            p95_s: samples.p95(),
            min_s: min,
        };
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Print the results table.
    pub fn report(&self) {
        println!("\n== bench: {} ==", self.title);
        println!("{:<44} {:>10} {:>10} {:>10} {:>10}", "name", "mean", "p50", "p95", "min");
        for r in &self.results {
            println!(
                "{:<44} {:>10} {:>10} {:>10} {:>10}",
                r.name,
                fmt_dur(r.mean_s),
                fmt_dur(r.p50_s),
                fmt_dur(r.p95_s),
                fmt_dur(r.min_s)
            );
        }
    }
}

/// Human duration formatting (ns/µs/ms/s).
pub fn fmt_dur(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// Guard: make sure a bench run completes within a budget (used to catch
/// accidental quadratic blowups in CI-ish runs).
pub fn assert_under(budget: Duration, f: impl FnOnce()) {
    let t0 = Instant::now();
    f();
    let dt = t0.elapsed();
    assert!(dt <= budget, "exceeded budget: {dt:?} > {budget:?}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_results() {
        let mut suite = BenchSuite::new("t").warmup(1).iters(5);
        let r = suite.bench("noop", || {}).clone();
        assert_eq!(r.iters, 5);
        assert!(r.mean_s >= 0.0);
        assert!(r.p95_s >= r.p50_s || (r.p95_s - r.p50_s).abs() < 1e-9);
        suite.report();
    }

    #[test]
    fn micro_measures_per_call() {
        let mut suite = BenchSuite::new("t").warmup(1).iters(3);
        let mut x = 0u64;
        let r = suite.bench_micro("add", 1000, || x = x.wrapping_add(1)).clone();
        assert!(r.mean_s < 1e-3, "per-call mean {}", r.mean_s);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_dur(5e-9).ends_with("ns"));
        assert!(fmt_dur(5e-6).ends_with("µs"));
        assert!(fmt_dur(5e-3).ends_with("ms"));
        assert!(fmt_dur(5.0).ends_with('s'));
    }
}
