//! Test & bench substrates (criterion / proptest substitutes, DESIGN.md §1).

pub mod bench;
pub mod engine;
pub mod prop;

pub use engine::TensorEngine;
