//! Test & bench substrates (criterion / proptest substitutes, DESIGN.md §1).

pub mod bench;
pub mod prop;
