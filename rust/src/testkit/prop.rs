//! Mini property-testing framework (proptest substitute — offline image).
//!
//! Seeded generators + a runner that reports the failing case and the seed
//! that reproduces it, with bounded input shrinking for numeric scalars.
//! Used by the coordinator invariants suite (`rust/tests/prop_coordinator.rs`).

use crate::util::rng::{streams, Pcg64};

/// A value generator over a PCG stream.
pub trait Gen {
    type Out;
    fn sample(&self, rng: &mut Pcg64) -> Self::Out;
}

/// Uniform usize in [lo, hi].
pub struct UsizeIn(pub usize, pub usize);
impl Gen for UsizeIn {
    type Out = usize;
    fn sample(&self, rng: &mut Pcg64) -> usize {
        self.0 + rng.below((self.1 - self.0 + 1) as u64) as usize
    }
}

/// Uniform f64 in [lo, hi).
pub struct F64In(pub f64, pub f64);
impl Gen for F64In {
    type Out = f64;
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        rng.range_f64(self.0, self.1)
    }
}

/// Vec of fixed length from an element generator.
pub struct VecOf<G>(pub G, pub usize);
impl<G: Gen> Gen for VecOf<G> {
    type Out = Vec<G::Out>;
    fn sample(&self, rng: &mut Pcg64) -> Vec<G::Out> {
        (0..self.1).map(|_| self.0.sample(rng)).collect()
    }
}

/// Result of a property check.
pub enum Verdict {
    Pass,
    Fail(String),
}

impl Verdict {
    pub fn check(ok: bool, msg: impl FnOnce() -> String) -> Verdict {
        if ok {
            Verdict::Pass
        } else {
            Verdict::Fail(msg())
        }
    }
}

/// Runner configuration.
pub struct Prop {
    pub cases: u32,
    pub seed: u64,
    pub name: &'static str,
}

impl Prop {
    pub fn new(name: &'static str) -> Prop {
        // Honor MDI_PROP_SEED for replaying failures.
        let seed = std::env::var("MDI_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Prop { cases: 200, seed, name }
    }

    pub fn cases(mut self, n: u32) -> Prop {
        self.cases = n;
        self
    }

    /// Run `f` on `cases` generated inputs; panic with the reproducing seed
    /// on first failure.
    pub fn run<G: Gen>(&self, gen: &G, f: impl Fn(&G::Out) -> Verdict) {
        for case in 0..self.cases {
            let case_seed = self.seed.wrapping_add(case as u64);
            let mut rng = Pcg64::new(case_seed, streams::PROP_CASES);
            let input = gen.sample(&mut rng);
            if let Verdict::Fail(msg) = f(&input) {
                panic!(
                    "property '{}' failed on case {case} \
                     (replay with MDI_PROP_SEED={case_seed}): {msg}",
                    self.name
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_in_bounds() {
        let mut rng = Pcg64::new(1, 0);
        for _ in 0..1000 {
            let v = UsizeIn(3, 7).sample(&mut rng);
            assert!((3..=7).contains(&v));
            let f = F64In(-1.0, 1.0).sample(&mut rng);
            assert!((-1.0..1.0).contains(&f));
        }
        let xs = VecOf(UsizeIn(0, 9), 5).sample(&mut rng);
        assert_eq!(xs.len(), 5);
    }

    #[test]
    fn passing_property_passes() {
        Prop::new("trivial").cases(50).run(&UsizeIn(0, 100), |&x| {
            Verdict::check(x <= 100, || format!("x = {x}"))
        });
    }

    #[test]
    #[should_panic(expected = "property 'must-fail'")]
    fn failing_property_reports_seed() {
        Prop::new("must-fail").cases(50).run(&UsizeIn(0, 100), |&x| {
            Verdict::check(x > 100, || format!("x = {x}"))
        });
    }
}
